"""Simulated DKV back store (HBase stand-in) with a calibrated latency model.

This container has no HBase; the back store is a real in-process KV map with
a *virtual-clock* latency model calibrated to the paper's setting (two
machines on a 100 Mbps LAN, HDD-backed region server):

  demand get (foreground):  rtt + items·service + bytes/bandwidth
  batched prefetch (background): one rtt per batch + per-item service
  write: acknowledged asynchronously (paper §4.4), accounted on the
         background channel.

Prefetches run on a dedicated background channel (the paper's low-priority
thread): they never serialize with demand fetches, but an item is only
*available* in cache once its batch completes — a demand read arriving
earlier blocks for the remainder (timeliness, §1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Sequence

import numpy as np

__all__ = ["Clock", "LatencyModel", "SimulatedDKVStore"]


class Clock:
    """Virtual time in seconds."""

    def __init__(self) -> None:
        self.now = 0.0

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


@dataclasses.dataclass
class LatencyModel:
    """Calibrated to the paper's testbed (§5 'Setting'): 100 Mbps network,
    7200 RPM HDD behind HBase's read path.  Service time carries lognormal
    jitter plus occasional long-tail stalls (compactions / GC pauses), so
    latency percentiles behave like a real store's."""

    rtt: float = 500e-6            # network round trip
    per_item_service: float = 150e-6  # store-side lookup/seek amortized
    bandwidth: float = 12.5e6      # bytes/s (100 Mbps)
    jitter_sigma: float = 0.25     # lognormal sigma on the service term
    stall_frac: float = 0.01       # long-tail stall probability
    stall_mult: float = 8.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def _jitter(self) -> float:
        j = float(np.exp(self._rng.normal(0.0, self.jitter_sigma)))
        if self._rng.random() < self.stall_frac:
            j *= self.stall_mult
        return j

    def get(self, n_items: int, total_bytes: int) -> float:
        base = (self.rtt + n_items * self.per_item_service
                + total_bytes / self.bandwidth)
        return base * self._jitter()

    def put(self, n_items: int, total_bytes: int) -> float:
        base = (self.rtt + n_items * self.per_item_service
                + total_bytes / self.bandwidth)
        return base * self._jitter()


class SimulatedDKVStore:
    """Wide-columnar KV store: keys are container keys, values are bytes."""

    def __init__(self, latency: Optional[LatencyModel] = None):
        self.latency = latency or LatencyModel()
        self.data: dict[Any, bytes] = {}
        self.background_free_at = 0.0  # prefetch channel availability
        self.write_free_at = 0.0       # write-behind channel (WAL path)
        self.gets = 0
        self.bytes_served = 0
        self._watchers: list[Callable[[Any], None]] = []

    # -- population ------------------------------------------------------
    def load(self, items: Iterable[tuple]) -> None:
        for k, v in items:
            self.data[k] = v

    # -- foreground (demand) path ----------------------------------------
    def get(self, key) -> tuple[Any, float]:
        """Returns (value, latency)."""
        v = self.data.get(key)
        size = len(v) if v is not None else 0
        self.gets += 1
        self.bytes_served += size
        return v, self.latency.get(1, size)

    def multi_get(self, keys: Sequence) -> tuple[list, float]:
        vals = [self.data.get(k) for k in keys]
        total = sum(len(v) for v in vals if v is not None)
        self.gets += len(keys)
        self.bytes_served += total
        return vals, self.latency.get(len(keys), total)

    def contains(self, key) -> bool:
        """Membership probe on store metadata (no data transfer, no latency
        charge — the client library caches the schema/key range map)."""
        return key in self.data

    # -- background channel (prefetch batches, async writes) --------------
    def backlog(self, now: float) -> float:
        """Outstanding work queued on the background channel, in seconds."""
        return max(0.0, self.background_free_at - now)

    def background_get(self, keys: Sequence, now: float) -> tuple[list, float]:
        """Issue a batched get on the background channel at virtual time
        ``now``; returns (values, completion_time)."""
        vals, lat = self.multi_get(keys)
        start = max(self.background_free_at, now)
        self.background_free_at = start + lat
        return vals, self.background_free_at

    def background_multi_get(
        self, keys: Sequence, now: float, backlog_cap: Optional[float] = None
    ) -> tuple[list, list]:
        """Store-agnostic prefetch API: batched background get returning
        *per-key* completion times (a sharded store completes each key when
        its owning node's batch lands).  With ``backlog_cap``, a batch whose
        channel is backlogged past the cap is shed (values come back None) —
        bounded I/O amplification, paper §1 'efficient'."""
        if backlog_cap is not None and self.backlog(now) > backlog_cap:
            return [None] * len(keys), [now] * len(keys)
        vals, done = self.background_get(keys, now)
        return vals, [done] * len(keys)

    def put(self, key, value: bytes, now: float) -> float:
        """Async write-behind: returns completion time on the write channel
        (the store's WAL path — writes never contend with prefetch reads);
        the caller does not block."""
        self.data[key] = value
        lat = self.latency.put(1, len(value))
        start = max(self.write_free_at, now)
        self.write_free_at = start + lat
        for w in self._watchers:
            w(key)
        return self.write_free_at

    # -- coherence monitor (co-processor / trigger stand-in, §4.4) --------
    def watch(self, callback: Callable[[Any], None]) -> None:
        """Register a cache-invalidation watcher, as an HBase co-processor
        or Cassandra trigger would notify client caches of updated items."""
        self._watchers.append(callback)
