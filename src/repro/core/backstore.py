"""Simulated DKV back store (HBase stand-in) with a calibrated latency model.

This container has no HBase; the back store is a real in-process KV map with
a *virtual-clock* latency model calibrated to the paper's setting (two
machines on a 100 Mbps LAN, HDD-backed region server):

  demand get (foreground):  rtt + items·service + bytes/bandwidth
  batched prefetch (background): one rtt per batch + per-item service
  write: acknowledged asynchronously (paper §4.4), accounted on the
         background channel.

Prefetches run on a dedicated background channel (the paper's low-priority
thread): they never serialize with demand fetches, but an item is only
*available* in cache once its batch completes — a demand read arriving
earlier blocks for the remainder (timeliness, §1).

Demand reads are *futures-based*: ``get_async`` / ``multi_get_async`` issue
the RPC on the node's demand channel (a fixed-width request pipeline, like
a region server's RPC handler pool) and return an :class:`RPCFuture`
carrying the issue time and the virtual completion time, so a client can
keep several reads in flight across nodes and account completion with
``max`` instead of ``sum`` — the read-path overlap that hides per-node
tail latency.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Optional, Sequence

import numpy as np

from .obs import NULL_TRACER, SPAN_RPC, SPAN_SERVICE

__all__ = ["Clock", "LatencyModel", "Channel", "RPCFuture",
           "SimulatedDKVStore"]


class Clock:
    """Virtual time in seconds."""

    def __init__(self) -> None:
        self.now = 0.0

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now

    def sync(self, t: float) -> float:
        """Jump forward to at least ``t`` (never backwards).  A client
        joining a running cluster must sync to the store's
        :meth:`~SimulatedDKVStore.frontier` first: store channels are
        shared, and reads issued from a lagging clock would be charged
        for queueing behind their own future."""
        self.now = max(self.now, float(t))
        return self.now


class Channel:
    """Fixed-width FIFO request pipeline on the virtual clock.

    ``width`` RPCs can be in service at once (a region server's handler
    pool); further requests queue behind the earliest-free lane.  Width 1
    recovers the strictly serial channel used for prefetch batches and the
    write-behind WAL path.

    Channels are shared store-side state: clients issuing against the same
    node must keep their virtual clocks roughly synchronized (the cluster
    drivers interleave tenants most-behind-first for exactly this reason).
    A client reading at a ``now`` far behind the channel frontier would be
    charged for queueing behind requests from its own future.
    """

    def __init__(self, width: int = 1):
        self.lanes = [0.0] * max(1, int(width))
        self.issued = 0          # RPCs admitted (ack accounting)

    @property
    def free_at(self) -> float:
        """When the earliest lane frees up (single-lane: the channel)."""
        return min(self.lanes)

    @free_at.setter
    def free_at(self, t: float) -> None:
        self.lanes = [float(t)] * len(self.lanes)

    def backlog(self, now: float) -> float:
        """Wait before a new request would enter service."""
        return max(0.0, self.free_at - now)

    def issue(self, now: float, service: float) -> float:
        """Enqueue one RPC at virtual time ``now``; returns completion."""
        i = min(range(len(self.lanes)), key=self.lanes.__getitem__)
        done = max(now, self.lanes[i]) + service
        self.lanes[i] = done
        self.issued += 1
        return done


@dataclasses.dataclass
class RPCFuture:
    """A demand read in flight: resolved values plus completion times on
    the virtual clock.  The store resolves values eagerly (the simulation
    knows them); *time* is what stays outstanding."""

    keys: tuple
    values: list
    issue_time: float
    done_at: float                       # when the whole RPC lands
    done_each: list = dataclasses.field(default_factory=list)  # per key
    node: Optional[int] = None           # serving node (sharded stores)
    #: missed-ack accounting for the failure detector: True when the RPC
    #: (or one of its attempts) expired instead of acking, and how many
    #: replica retries the coordinator paid before this future resolved
    timed_out: bool = False
    retries: int = 0
    #: the chaos engine dropped this message before it reached the node —
    #: the sender sees exactly a timeout (no ack), but the node never
    #: served it (no counters moved), so the coordinator must retry
    dropped: bool = False

    def result(self) -> tuple[list, float]:
        return self.values, self.done_at

    def value(self):
        """Single-key convenience."""
        return self.values[0]

    def wait(self, now: float) -> float:
        """Remaining in-flight time as seen from ``now``."""
        return max(0.0, self.done_at - now)


@dataclasses.dataclass
class LatencyModel:
    """Calibrated to the paper's testbed (§5 'Setting'): 100 Mbps network,
    7200 RPM HDD behind HBase's read path.  Service time carries lognormal
    jitter plus occasional long-tail stalls (compactions / GC pauses), so
    latency percentiles behave like a real store's."""

    rtt: float = 500e-6            # network round trip
    per_item_service: float = 150e-6  # store-side lookup/seek amortized
    bandwidth: float = 12.5e6      # bytes/s (100 Mbps)
    jitter_sigma: float = 0.25     # lognormal sigma on the service term
    stall_frac: float = 0.01       # long-tail stall probability
    stall_mult: float = 8.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def _jitter(self) -> float:
        j = float(np.exp(self._rng.normal(0.0, self.jitter_sigma)))
        if self._rng.random() < self.stall_frac:
            j *= self.stall_mult
        return j

    def get(self, n_items: int, total_bytes: int) -> float:
        base = (self.rtt + n_items * self.per_item_service
                + total_bytes / self.bandwidth)
        return base * self._jitter()

    def put(self, n_items: int, total_bytes: int) -> float:
        base = (self.rtt + n_items * self.per_item_service
                + total_bytes / self.bandwidth)
        return base * self._jitter()


class SimulatedDKVStore:
    """Wide-columnar KV store: keys are container keys, values are bytes."""

    #: demand RPC handler pool per node — concurrent clients' in-flight
    #: reads pipeline through these lanes instead of magically overlapping
    DEMAND_WIDTH = 4

    def __init__(self, latency: Optional[LatencyModel] = None,
                 demand_width: int = DEMAND_WIDTH):
        self.latency = latency or LatencyModel()
        self.data: dict[Any, bytes] = {}
        #: per-key write version, stamped by a replicating front-end
        #: (ShardedDKVStore's put frontier).  Replicas whose version for a
        #: key trails the newest are *stale* — the signal read-repair and
        #: hinted-handoff draining converge on.  The node itself is
        #: version-agnostic: values are ints (legacy monotone counters) or
        #: ``repro.core.versions.DottedVersion`` objects, both totally
        #: ordered, with absent == version 0.  A standalone node never
        #: populates it.
        self.versions: dict[Any, Any] = {}
        self.demand = Channel(demand_width)     # foreground RPC pipeline
        self.background = Channel(1)   # prefetch channel
        self.write_channel = Channel(1)  # write-behind channel (WAL path)
        self.gets = 0
        self.bytes_served = 0
        #: crashed == the process is gone: RPCs to this node never ack (the
        #: sharded front-end observes timeouts and feeds the failure
        #: detector).  Unlike ``ShardedDKVStore.set_down`` — a *declared*
        #: verdict the router consults — a crash is invisible until traffic
        #: runs into it, which is exactly what emergent detection needs.
        self.crashed = False
        #: EWMA of per-item demand service time — the "how fast is this
        #: node lately" signal replica-aware routing steers by
        self.ewma_service: Optional[float] = None
        self._watchers: list[Callable[[Any], None]] = []
        #: chaos injection hook (see ``repro.core.chaos``): when wired, the
        #: RPC entry points below consult the engine for every message that
        #: names its sender via ``src`` — partitions and drops surface as
        #: ``RPCFuture.dropped`` / ``None`` acks, link delay lands on the
        #: completion time.  These entry points are the *only* sanctioned
        #: way for a coordinator to reach this node's channels (palplint
        #: PALP104 flags direct ``Channel.issue`` sends that bypass them).
        self.chaos = None
        self.node_id: Optional[int] = None
        #: Palpascope hook: RPC entry points open child spans on this
        #: tracer.  NULL_TRACER's methods are constant no-ops, so an
        #: untraced store pays a few method calls per RPC and nothing
        #: else (gated by bench_overhead's tracing_overhead_ratio).
        self.tracer = NULL_TRACER

    # channel aliases (pre-futures API surface, kept for tests/tools)
    @property
    def background_free_at(self) -> float:
        return self.background.free_at

    @background_free_at.setter
    def background_free_at(self, t: float) -> None:
        self.background.free_at = t

    @property
    def write_free_at(self) -> float:
        return self.write_channel.free_at

    @write_free_at.setter
    def write_free_at(self, t: float) -> None:
        self.write_channel.free_at = t

    # -- population ------------------------------------------------------
    def load(self, items: Iterable[tuple]) -> None:
        for k, v in items:
            self.data[k] = v

    # -- failure injection ------------------------------------------------
    def crash(self) -> None:
        """Kill the node: in-flight and future RPCs stop acking.  Nothing
        is *declared* anywhere — detection must emerge from traffic."""
        self.crashed = True

    def recover(self) -> None:
        """The process is back (data intact, possibly stale).  Again
        nothing is declared: the cluster notices via probe acks."""
        self.crashed = False

    # -- chaos injection chokepoint ---------------------------------------
    def connect_chaos(self, engine, node_id: int) -> None:
        """Wire a ``ChaosEngine`` onto this node's RPC entry points."""
        self.chaos = engine
        self.node_id = node_id

    def _chaos_send(self, now: float, src) -> tuple[bool, float, int]:
        """Adjudicate one inbound message on the ``src -> this node`` link.

        Returns ``(delivered, entry_time, duplicates)``.  Without a wired
        engine or a named sender the message passes untouched — standalone
        stores and legacy call sites pay nothing for the hook.
        """
        if self.chaos is None or src is None:
            return True, now, 0
        ok, delay, dups = self.chaos.on_send(now, src, self.node_id)
        return ok, now + delay, dups

    # -- foreground (demand) path ----------------------------------------
    def _note_service(self, latency: float, n_items: int) -> None:
        per_item = latency / max(1, n_items)
        if self.ewma_service is None:
            self.ewma_service = per_item
        else:
            self.ewma_service = 0.8 * self.ewma_service + 0.2 * per_item

    def _serve(self, keys: Sequence) -> tuple[list, float]:
        """Look up + sample latency; no EWMA update (shared by the demand
        and background paths — only demand service feeds routing)."""
        vals = [self.data.get(k) for k in keys]
        total = sum(len(v) for v in vals if v is not None)
        self.gets += len(keys)
        self.bytes_served += total
        return vals, self.latency.get(len(keys), total)

    def get(self, key) -> tuple[Any, float]:
        """Returns (value, latency)."""
        vals, lat = self._serve((key,))
        self._note_service(lat, 1)
        return vals[0], lat

    def multi_get(self, keys: Sequence) -> tuple[list, float]:
        vals, lat = self._serve(keys)
        self._note_service(lat, len(keys))
        return vals, lat

    def _trace_rpc(self, sp, now: float, entry: float, done: float,
                   src, dups: int, n_keys: int) -> None:
        """Annotate a delivered demand RPC: chaos link delay/duplication
        as fields, the node-side interval as a ``service`` child (the
        span a chaos-dropped RPC conspicuously lacks)."""
        tr = self.tracer
        sp.set(node=self.node_id, src=src, n_keys=n_keys)
        if entry > now:
            sp.set(link_delay=entry - now)
        if dups:
            sp.set(duplicates=dups)
        ssp = tr.span(SPAN_SERVICE, entry)
        tr.end(ssp, done)
        sp.finish(done)

    def get_async(self, key, now: float, src=None) -> RPCFuture:
        """Issue a demand read on the node's RPC pipeline; never blocks.
        The future's ``done_at`` accounts queueing behind other in-flight
        demand reads (handler-pool contention)."""
        tr = self.tracer
        sp = tr.span(SPAN_RPC, now)
        ok, entry, dups = self._chaos_send(now, src)
        if not ok:
            if sp.live:
                sp.mark("dropped").set(node=self.node_id, src=src,
                                       reason=getattr(self.chaos,
                                                      "last_drop_reason",
                                                      None))
            tr.end(sp, now)
            return RPCFuture((key,), [None], now, now, done_each=[now],
                             timed_out=True, dropped=True)
        v, lat = self.get(key)
        done = self.demand.issue(entry, lat)
        for _ in range(dups):  # duplicate delivery: wasted handler service
            self.demand.issue(entry, lat)
        if sp.live:
            self._trace_rpc(sp, now, entry, done, src, dups, 1)
        tr.end(sp)
        return RPCFuture((key,), [v], now, done, done_each=[done])

    def multi_get_async(self, keys: Sequence, now: float,
                        src=None) -> RPCFuture:
        """Batched demand read as one pipelined RPC."""
        tr = self.tracer
        sp = tr.span(SPAN_RPC, now)
        ok, entry, dups = self._chaos_send(now, src)
        if not ok:
            if sp.live:
                sp.mark("dropped").set(node=self.node_id, src=src,
                                       reason=getattr(self.chaos,
                                                      "last_drop_reason",
                                                      None))
            tr.end(sp, now)
            return RPCFuture(tuple(keys), [None] * len(keys), now, now,
                             done_each=[now] * len(keys),
                             timed_out=True, dropped=True)
        vals, lat = self.multi_get(keys)
        done = self.demand.issue(entry, lat)
        for _ in range(dups):
            self.demand.issue(entry, lat)
        if sp.live:
            self._trace_rpc(sp, now, entry, done, src, dups, len(keys))
        tr.end(sp)
        return RPCFuture(tuple(keys), vals, now, done,
                         done_each=[done] * len(keys))

    def demand_backlog(self, now: float) -> float:
        """Queueing delay a new demand read would see right now."""
        return self.demand.backlog(now)

    def frontier(self) -> float:
        """The furthest virtual time any channel has been driven to — the
        join point for a new client's clock (see :meth:`Clock.sync`)."""
        return max(max(self.demand.lanes), max(self.background.lanes),
                   max(self.write_channel.lanes))

    def contains(self, key) -> bool:
        """Membership probe on store metadata (no data transfer, no latency
        charge — the client library caches the schema/key range map)."""
        return key in self.data

    # -- background channel (prefetch batches, async writes) --------------
    def backlog(self, now: float) -> float:
        """Outstanding work queued on the background channel, in seconds."""
        return self.background.backlog(now)

    def background_get(self, keys: Sequence, now: float,
                       src=None) -> tuple[list, float]:
        """Issue a batched get on the background channel at virtual time
        ``now``; returns (values, completion_time).  Does not touch the
        demand-service EWMA: amortized batch service would make prefetch-
        heavy nodes look faster to demand routing than they are.  A chaos
        drop sheds the whole batch and returns ``(None, now)`` — distinct
        from a backlog-cap shed's ``[None, ...]`` values so the caller can
        feed the missed ack to its failure detector."""
        tr = self.tracer
        sp = tr.span(SPAN_RPC, now)
        ok, entry, _ = self._chaos_send(now, src)
        if not ok:
            if sp.live:
                sp.mark("dropped").set(node=self.node_id, src=src,
                                       background=True)
            tr.end(sp, now)
            return None, now
        vals, lat = self._serve(keys)
        done = self.background.issue(entry, lat)
        if sp.live:
            # background work: the span closes at issue time (it must
            # nest in the foreground op that caused it); the batch's
            # landing time rides along as a field
            sp.set(node=self.node_id, src=src, n_keys=len(keys),
                   background=True, done_at=done)
        tr.end(sp, entry)
        return vals, done

    def background_multi_get(
        self, keys: Sequence, now: float, backlog_cap: Optional[float] = None
    ) -> tuple[list, list]:
        """Store-agnostic prefetch API: batched background get returning
        *per-key* completion times (a sharded store completes each key when
        its owning node's batch lands).  With ``backlog_cap``, a batch whose
        channel is backlogged past the cap is shed (values come back None) —
        bounded I/O amplification, paper §1 'efficient'."""
        if backlog_cap is not None and self.backlog(now) > backlog_cap:
            return [None] * len(keys), [now] * len(keys)
        vals, done = self.background_get(keys, now)
        return vals, [done] * len(keys)

    def put(self, key, value: bytes, now: float, src=None) -> Optional[float]:
        """Async write-behind: returns completion time on the write channel
        (the store's WAL path — writes never contend with prefetch reads);
        the caller does not block.  Returns ``None`` when the chaos engine
        dropped the message — the write never reached this node, the
        coordinator sees a missed ack and must hint/retry."""
        tr = self.tracer
        sp = tr.span(SPAN_RPC, now)
        ok, entry, dups = self._chaos_send(now, src)
        if not ok:
            if sp.live:
                sp.mark("dropped").set(node=self.node_id, src=src,
                                       write=True)
            tr.end(sp, now)
            return None
        self.data[key] = value
        lat = self.latency.put(1, len(value))
        done = self.write_channel.issue(entry, lat)
        for _ in range(dups):  # duplicate delivery: idempotent re-apply
            self.write_channel.issue(entry, lat)
        if sp.live:
            sp.set(node=self.node_id, src=src, write=True, done_at=done)
            if dups:
                sp.set(duplicates=dups)
        tr.end(sp, entry)
        for w in self._watchers:
            w(key)
        return done

    def apply_replica_write(self, key, value: bytes, version,
                            now: float, src=None) -> Optional[float]:
        """Install a *replicated* write — value and version together, as one
        message — on this node's write channel.  This is the sanctioned
        chokepoint for read-repair, hinted-handoff drains, and any other
        coordinator-to-replica transfer (PALP104 flags the direct-channel
        sends this replaces).  Returns the completion time, or ``None``
        when chaos dropped the message (nothing applied)."""
        tr = self.tracer
        sp = tr.span(SPAN_RPC, now)
        ok, entry, dups = self._chaos_send(now, src)
        if not ok:
            if sp.live:
                sp.mark("dropped").set(node=self.node_id, src=src,
                                       replica_write=True)
            tr.end(sp, now)
            return None
        self.data[key] = value
        self.versions[key] = version
        lat = self.latency.put(1, len(value))
        done = self.write_channel.issue(entry, lat)
        for _ in range(dups):
            self.write_channel.issue(entry, lat)
        if sp.live:
            sp.set(node=self.node_id, src=src, replica_write=True,
                   done_at=done)
        tr.end(sp, entry)
        # deliberately no watcher fire: repair/drain installs the value
        # clients already observed at write time — no invalidation storm
        return done

    def bulk_apply(self, items: Sequence[tuple], now: float,
                   src=None) -> Optional[float]:
        """Install a batch of ``(key, value, version)`` records as one
        streamed message on the write channel (membership range transfers).
        One latency charge for the whole batch; ``None`` on a chaos drop
        (the stream batch must be resent)."""
        ok, entry, _ = self._chaos_send(now, src)
        if not ok:
            return None
        nbytes = 0
        for key, value, version in items:
            self.data[key] = value
            self.versions[key] = version
            nbytes += len(value)
        lat = self.latency.put(len(items), nbytes)
        return self.write_channel.issue(entry, lat)

    # -- coherence monitor (co-processor / trigger stand-in, §4.4) --------
    def watch(self, callback: Callable[[Any], None]) -> None:
        """Register a cache-invalidation watcher, as an HBase co-processor
        or Cassandra trigger would notify client caches of updated items."""
        self._watchers.append(callback)
