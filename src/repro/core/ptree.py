"""Probabilistic trees (Palpatine §4.2, Figure 3).

The metastore's frequent sequences are compiled into a forest of
probabilistic trees (akin to Markov chains): node = accessed item, edge =
transition with a probability estimated from observed sequence frequencies.
One tree per distinct first item; roots are indexed by item so a client
request can be matched in O(1).

Node probabilities:
  * ``prob``     — conditional: P(child | parent reached), normalized over
                   siblings by pattern support mass.
  * ``cum_prob`` — cumulative from the root: probability the item is
                   requested when starting from the root (used by the
                   fetch-top-n heuristic, level-order + probability-wise).

``PTreeIndex.flatten`` compiles a finished generation of trees into a
:class:`FlatForest` — one CSR-style array bundle over the whole forest —
so the vectorized decision engine (:mod:`repro.core.decision`) can walk
every live prefetch context in a single array program instead of one
Python pointer chase per context.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Iterable, Iterator, Optional

import numpy as np

from .mining import Pattern

__all__ = ["PNode", "PTree", "PTreeIndex", "FlatForest"]


class PNode:
    __slots__ = ("item", "weight", "prob", "cum_prob", "depth", "children", "parent")

    def __init__(self, item: int, depth: int, parent: Optional["PNode"]):
        self.item = item
        self.weight = 0.0      # support mass flowing through this node
        self.prob = 1.0        # P(this | parent)
        self.cum_prob = 1.0    # P(this | root)
        self.depth = depth
        self.children: dict[int, PNode] = {}
        self.parent = parent

    def level_order(self) -> Iterator["PNode"]:
        queue = deque((self,))
        while queue:
            node = queue.popleft()
            yield node
            queue.extend(node.children.values())

    def __repr__(self) -> str:
        return f"PNode({self.item}, p={self.prob:.2f}, cp={self.cum_prob:.2f})"


class PTree:
    """A tree rooted at one first-item; paths are mined frequent sequences."""

    def __init__(self, root_item: int):
        self.root = PNode(root_item, depth=0, parent=None)
        self.max_depth = 0

    def insert(self, items: tuple, support: int) -> None:
        assert items[0] == self.root.item
        node = self.root
        node.weight += support
        for it in items[1:]:
            child = node.children.get(it)
            if child is None:
                child = PNode(it, node.depth + 1, node)
                node.children[it] = child
            child.weight += support
            node = child
        self.max_depth = max(self.max_depth, len(items) - 1)

    def finalize(self) -> None:
        """Normalize sibling weights into conditional + cumulative probs."""
        for node in self.root.level_order():
            total = sum(c.weight for c in node.children.values())
            for c in node.children.values():
                c.prob = (c.weight / total) if total > 0 else 0.0
                c.cum_prob = node.cum_prob * c.prob

    # -- queries used by the heuristics --------------------------------
    def nodes_below(self) -> Iterator[PNode]:
        """All non-root nodes, level order."""
        it = self.root.level_order()
        next(it)  # skip root
        return it

    def top_n_cumulative(self, n: int) -> list[PNode]:
        """The n non-root nodes with highest cumulative probability,
        returned level-order first, probability-wise second (paper §4.5)."""
        best = heapq.nlargest(
            n, self.nodes_below(), key=lambda nd: (nd.cum_prob, -nd.depth)
        )
        return sorted(best, key=lambda nd: (nd.depth, -nd.cum_prob))

    def levels(self, lo: int, hi: int) -> list[PNode]:
        """Nodes with lo <= depth <= hi, level order."""
        return [nd for nd in self.nodes_below() if lo <= nd.depth <= hi]

    def walk(self, path: tuple) -> Optional[PNode]:
        """Follow ``path`` (item ids, starting at the root item) down the
        tree; None if it diverges."""
        if not path or path[0] != self.root.item:
            return None
        node = self.root
        for it in path[1:]:
            node = node.children.get(it)
            if node is None:
                return None
        return node

    def size(self) -> int:
        return sum(1 for _ in self.root.level_order())


@dataclasses.dataclass(frozen=True)
class FlatForest:
    """A finished tree generation flattened into CSR-style arrays.

    Node ids are assigned per tree in level (BFS) order, trees
    concatenated, which buys three invariants the vectorized walk relies
    on:

    * tree ``t`` owns the id range ``[tree_start[t], tree_start[t+1])``
      and ``tree_start[t]`` is its root;
    * within a tree the ids are level-ordered, so any node subset sorted
      by id is already in the wave order the scalar engine emits;
    * the children of node ``v`` are the contiguous ids
      ``[first_child[v], first_child[v] + n_children[v])``.

    ``pre``/``post`` carry each node's DFS preorder interval (``u`` is in
    ``v``'s subtree iff ``pre[v] <= pre[u] < post[v]``), and ``level_key =
    tree_of * depth_stride + depth`` is globally non-decreasing, so one
    batched ``searchsorted`` finds any per-tree depth band.  Edges are a
    sorted ``parent_id * item_stride + item`` key table: advancing C live
    contexts by the requested item is one ``searchsorted`` over C keys.
    """

    items: np.ndarray         # int64[n]  item id per node
    depth: np.ndarray         # int64[n]
    prob: np.ndarray          # float64[n]  P(node | parent)
    cum_prob: np.ndarray      # float64[n]  P(node | root)
    first_child: np.ndarray   # int64[n]
    n_children: np.ndarray    # int64[n]
    pre: np.ndarray           # int64[n]  DFS preorder rank
    post: np.ndarray          # int64[n]  subtree end (preorder interval)
    tree_of: np.ndarray       # int64[n]
    tree_start: np.ndarray    # int64[T+1]
    tree_max_depth: np.ndarray  # int64[T]
    level_key: np.ndarray     # int64[n]  tree_of * depth_stride + depth
    depth_stride: int
    edge_keys: np.ndarray     # int64[E]  sorted parent * item_stride + item
    edge_child: np.ndarray    # int64[E]
    item_stride: int
    root_tree: dict           # {root item -> tree index}

    @property
    def n_nodes(self) -> int:
        return len(self.items)

    @property
    def n_trees(self) -> int:
        return len(self.tree_max_depth)

    def level_band(self, trees: np.ndarray, lo: np.ndarray,
                   hi: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Batched per-tree depth-band lookup: node-id ranges ``[a, b)``
        covering depths ``[lo, hi]`` of each queried tree."""
        d = self.depth_stride
        a = np.searchsorted(self.level_key, trees * d + lo, side="left")
        b = np.searchsorted(self.level_key, trees * d + hi + 1, side="left")
        return a, b


class PTreeIndex:
    """Hash table of trees keyed by the first item of the frequent sequences
    (paper §4.5: 'hash tables of trees whose keys represent the first items').
    """

    def __init__(self):
        self.trees: dict[int, PTree] = {}

    @classmethod
    def build(cls, patterns: Iterable[Pattern]) -> "PTreeIndex":
        idx = cls()
        for p in patterns:
            if len(p.items) < 2:
                # a length-1 pattern would build a depth-0 tree whose
                # progressive context has an empty initial wave and can
                # never advance — a do-nothing context that only burns a
                # slot for an op; never create the tree at all
                continue
            tree = idx.trees.get(p.items[0])
            if tree is None:
                tree = idx.trees[p.items[0]] = PTree(p.items[0])
            tree.insert(p.items, p.support)
        for tree in idx.trees.values():
            tree.finalize()
        return idx

    def match_root(self, item: int) -> Optional[PTree]:
        return self.trees.get(item)

    def __len__(self) -> int:
        return len(self.trees)

    def flatten(self) -> FlatForest:
        """Compile this generation into the :class:`FlatForest` array
        bundle (done once per ``replace_index``, amortized over every
        subsequent request)."""
        order: list[PNode] = []
        tree_of: list[int] = []
        tree_start = [0]
        tree_maxd: list[int] = []
        root_tree: dict = {}
        for t, (ritem, tree) in enumerate(self.trees.items()):
            root_tree[ritem] = t
            sub = list(tree.root.level_order())
            order.extend(sub)
            tree_of.extend([t] * len(sub))
            tree_start.append(len(order))
            tree_maxd.append(tree.max_depth)
        n = len(order)
        id_of = {id(nd): i for i, nd in enumerate(order)}
        items = np.empty(n, np.int64)
        depth = np.empty(n, np.int64)
        prob = np.empty(n, np.float64)
        cum = np.empty(n, np.float64)
        first_child = np.zeros(n, np.int64)
        n_children = np.zeros(n, np.int64)
        for i, nd in enumerate(order):
            items[i] = nd.item
            depth[i] = nd.depth
            prob[i] = nd.prob
            cum[i] = nd.cum_prob
            if nd.children:
                # BFS hands children consecutive ids in dict order, so
                # the first child in dict order holds the lowest id
                first_child[i] = id_of[id(next(iter(nd.children.values())))]
                n_children[i] = len(nd.children)
        # DFS preorder intervals for O(1) subtree membership
        pre = np.zeros(n, np.int64)
        post = np.zeros(n, np.int64)
        counter = 0
        for t in range(len(tree_maxd)):
            stack = [(tree_start[t], False)]
            while stack:
                v, done = stack.pop()
                if done:
                    post[v] = counter
                    continue
                pre[v] = counter
                counter += 1
                stack.append((v, True))
                fc, k = first_child[v], n_children[v]
                # push in reverse so the first child is visited first
                for c in range(fc + k - 1, fc - 1, -1):
                    stack.append((int(c), False))
        max_depth = int(depth.max()) if n else 0
        depth_stride = max_depth + 2
        tof = np.asarray(tree_of, np.int64)
        level_key = tof * depth_stride + depth
        item_stride = int(items.max()) + 1 if n else 1
        child_ids = np.flatnonzero(depth > 0)
        parents = np.empty(len(child_ids), np.int64)
        for j, c in enumerate(child_ids):
            parents[j] = id_of[id(order[c].parent)]
        ekeys = parents * item_stride + items[child_ids]
        o = np.argsort(ekeys, kind="stable")
        return FlatForest(
            items=items, depth=depth, prob=prob, cum_prob=cum,
            first_child=first_child, n_children=n_children,
            pre=pre, post=post, tree_of=tof,
            tree_start=np.asarray(tree_start, np.int64),
            tree_max_depth=np.asarray(tree_maxd, np.int64),
            level_key=level_key, depth_stride=depth_stride,
            edge_keys=ekeys[o], edge_child=child_ids[o].astype(np.int64),
            item_stride=item_stride, root_tree=root_tree,
        )
