"""Probabilistic trees (Palpatine §4.2, Figure 3).

The metastore's frequent sequences are compiled into a forest of
probabilistic trees (akin to Markov chains): node = accessed item, edge =
transition with a probability estimated from observed sequence frequencies.
One tree per distinct first item; roots are indexed by item so a client
request can be matched in O(1).

Node probabilities:
  * ``prob``     — conditional: P(child | parent reached), normalized over
                   siblings by pattern support mass.
  * ``cum_prob`` — cumulative from the root: probability the item is
                   requested when starting from the root (used by the
                   fetch-top-n heuristic, level-order + probability-wise).
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Iterable, Iterator, Optional

from .mining import Pattern

__all__ = ["PNode", "PTree", "PTreeIndex"]


class PNode:
    __slots__ = ("item", "weight", "prob", "cum_prob", "depth", "children", "parent")

    def __init__(self, item: int, depth: int, parent: Optional["PNode"]):
        self.item = item
        self.weight = 0.0      # support mass flowing through this node
        self.prob = 1.0        # P(this | parent)
        self.cum_prob = 1.0    # P(this | root)
        self.depth = depth
        self.children: dict[int, PNode] = {}
        self.parent = parent

    def level_order(self) -> Iterator["PNode"]:
        queue = deque((self,))
        while queue:
            node = queue.popleft()
            yield node
            queue.extend(node.children.values())

    def __repr__(self) -> str:
        return f"PNode({self.item}, p={self.prob:.2f}, cp={self.cum_prob:.2f})"


class PTree:
    """A tree rooted at one first-item; paths are mined frequent sequences."""

    def __init__(self, root_item: int):
        self.root = PNode(root_item, depth=0, parent=None)
        self.max_depth = 0

    def insert(self, items: tuple, support: int) -> None:
        assert items[0] == self.root.item
        node = self.root
        node.weight += support
        for it in items[1:]:
            child = node.children.get(it)
            if child is None:
                child = PNode(it, node.depth + 1, node)
                node.children[it] = child
            child.weight += support
            node = child
        self.max_depth = max(self.max_depth, len(items) - 1)

    def finalize(self) -> None:
        """Normalize sibling weights into conditional + cumulative probs."""
        for node in self.root.level_order():
            total = sum(c.weight for c in node.children.values())
            for c in node.children.values():
                c.prob = (c.weight / total) if total > 0 else 0.0
                c.cum_prob = node.cum_prob * c.prob

    # -- queries used by the heuristics --------------------------------
    def nodes_below(self) -> Iterator[PNode]:
        """All non-root nodes, level order."""
        it = self.root.level_order()
        next(it)  # skip root
        return it

    def top_n_cumulative(self, n: int) -> list[PNode]:
        """The n non-root nodes with highest cumulative probability,
        returned level-order first, probability-wise second (paper §4.5)."""
        best = heapq.nlargest(
            n, self.nodes_below(), key=lambda nd: (nd.cum_prob, -nd.depth)
        )
        return sorted(best, key=lambda nd: (nd.depth, -nd.cum_prob))

    def levels(self, lo: int, hi: int) -> list[PNode]:
        """Nodes with lo <= depth <= hi, level order."""
        return [nd for nd in self.nodes_below() if lo <= nd.depth <= hi]

    def walk(self, path: tuple) -> Optional[PNode]:
        """Follow ``path`` (item ids, starting at the root item) down the
        tree; None if it diverges."""
        if not path or path[0] != self.root.item:
            return None
        node = self.root
        for it in path[1:]:
            node = node.children.get(it)
            if node is None:
                return None
        return node

    def size(self) -> int:
        return sum(1 for _ in self.root.level_order())


class PTreeIndex:
    """Hash table of trees keyed by the first item of the frequent sequences
    (paper §4.5: 'hash tables of trees whose keys represent the first items').
    """

    def __init__(self):
        self.trees: dict[int, PTree] = {}

    @classmethod
    def build(cls, patterns: Iterable[Pattern]) -> "PTreeIndex":
        idx = cls()
        for p in patterns:
            if not p.items:
                continue
            tree = idx.trees.get(p.items[0])
            if tree is None:
                tree = idx.trees[p.items[0]] = PTree(p.items[0])
            tree.insert(p.items, p.support)
        for tree in idx.trees.values():
            tree.finalize()
        return idx

    def match_root(self, item: int) -> Optional[PTree]:
        return self.trees.get(item)

    def __len__(self) -> int:
        return len(self.trees)
