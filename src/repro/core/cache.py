"""Two-space KV cache (Palpatine §4.4).

Two independent LRU spaces: the *main* space holds demand-fetched items, the
*preemptive* space holds prefetched items (a configurable fraction of the
main size, 10 % by default).  The separation bounds cache pollution: useless
prefetches can only churn the preemptive space.  A first access to a
prefetched item counts as a *prefetch hit* and promotes it to the main space;
later accesses are plain cache hits (paper §5.2 "Accuracy").
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Optional

from .obs import AttributionTable

__all__ = ["CacheStats", "LRUSpace", "TwoSpaceCache"]


@dataclasses.dataclass
class CacheStats:
    accesses: int = 0
    hits: int = 0                # all accesses served by cache (both spaces)
    misses: int = 0
    prefetches: int = 0          # prefetched items admitted
    prefetch_hits: int = 0       # first access to a prefetched item
    prefetch_waits: int = 0      # prefetch hit arrived while still in flight
    invalidations: int = 0
    writes: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / self.accesses if self.accesses else 0.0

    @property
    def precision(self) -> float:
        return self.prefetch_hits / self.prefetches if self.prefetches else 0.0


@dataclasses.dataclass
class _Entry:
    value: Any
    size: int
    available_at: float = 0.0    # prefetch completion time (virtual clock)
    cause: Any = None            # PrefetchCause for attribution (or None)


class LRUSpace:
    """Byte-capacity LRU.  ``evict_cb``, when set, observes every
    capacity eviction as ``(key, entry)`` — the attribution hook for
    prefetched-but-never-touched entries leaving the preemptive space."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self.used = 0
        self.od: "OrderedDict[Any, _Entry]" = OrderedDict()
        self.evict_cb = None

    def __contains__(self, key) -> bool:
        return key in self.od

    def __len__(self) -> int:
        return len(self.od)

    def get(self, key) -> Optional[_Entry]:
        e = self.od.get(key)
        if e is not None:
            self.od.move_to_end(key)
        return e

    def peek(self, key) -> Optional[_Entry]:
        return self.od.get(key)

    def put(self, key, entry: _Entry) -> list:
        """Insert/replace; returns evicted keys."""
        old = self.od.pop(key, None)
        if old is not None:
            self.used -= old.size
        if entry.size > self.capacity:
            # cannot fit at all (incl. capacity == 0) — but any previous
            # entry under this key is already gone: keeping it would serve
            # the superseded value on the next lookup
            return []
        self.od[key] = entry
        self.used += entry.size
        evicted = []
        while self.used > self.capacity:
            k, e = self.od.popitem(last=False)
            self.used -= e.size
            evicted.append(k)
            if self.evict_cb is not None:
                self.evict_cb(k, e)
        return evicted

    def remove(self, key) -> bool:
        e = self.od.pop(key, None)
        if e is not None:
            self.used -= e.size
            return True
        return False

    def resize(self, capacity_bytes: int) -> list:
        """Change the byte budget in place (cluster budget rebalancing).
        Shrinking evicts LRU-first down to the new capacity; returns the
        evicted keys."""
        self.capacity = int(capacity_bytes)
        evicted = []
        while self.used > self.capacity:
            k, e = self.od.popitem(last=False)
            self.used -= e.size
            evicted.append(k)
            if self.evict_cb is not None:
                self.evict_cb(k, e)
        return evicted


class TwoSpaceCache:
    def __init__(self, main_bytes: int, preemptive_frac: float = 0.10):
        self.preemptive_frac = float(preemptive_frac)
        self.main = LRUSpace(main_bytes)
        self.preemptive = LRUSpace(int(main_bytes * preemptive_frac))
        self.stats = CacheStats()
        # per-pattern prefetch attribution (Palpascope): every admitted
        # prefetch ends up hit, unused, or resident — the table's hit
        # sum equals stats.prefetch_hits exactly (tier-1 pinned)
        self.attr = AttributionTable()
        self.preemptive.evict_cb = self._prefetch_evicted

    def _prefetch_evicted(self, key, e: _Entry) -> None:
        self.attr.record_unused(e.cause, e.size)

    def reset_attr(self) -> None:
        self.attr = AttributionTable()

    def resize(self, main_bytes: int) -> None:
        """Re-budget both spaces, keeping the preemptive fraction; overflow
        evicts LRU-first (the rebalancer shrinks cold partitions live)."""
        self.main.resize(main_bytes)
        self.preemptive.resize(int(main_bytes * self.preemptive_frac))

    # -- reads ---------------------------------------------------------
    def lookup(self, key, now: float = 0.0):
        """Returns ``(value, wait)`` on hit, ``None`` on miss.

        ``wait`` > 0 means the item was prefetched but is still in flight;
        the caller blocks for the remainder (paper: timeliness).
        """
        self.stats.accesses += 1
        e = self.main.get(key)
        if e is not None:
            self.stats.hits += 1
            return e.value, 0.0
        e = self.preemptive.peek(key)
        if e is not None:
            # first touch of a prefetched item: prefetch hit + promotion
            self.preemptive.remove(key)
            wait = max(0.0, e.available_at - now)
            self.stats.hits += 1
            self.stats.prefetch_hits += 1
            self.attr.record_hit(e.cause, e.size)
            if wait > 0:
                self.stats.prefetch_waits += 1
            self.main.put(key, _Entry(e.value, e.size))
            return e.value, wait
        self.stats.misses += 1
        return None

    def contains(self, key) -> bool:
        return key in self.main or key in self.preemptive

    # -- fills -----------------------------------------------------------
    def put_demand(self, key, value, size: int) -> None:
        old = self.preemptive.peek(key)
        if old is not None:
            # a demand fetch raced the prefetched copy: the prefetch
            # never got its first touch — pure waste
            self.attr.record_unused(old.cause, old.size)
        self.preemptive.remove(key)
        self.main.put(key, _Entry(value, size))

    def put_prefetch(self, key, value, size: int, available_at: float,
                     cause=None) -> bool:
        """Admit a prefetched item (skips items already cached).  Returns
        True if admitted (counted against precision)."""
        if key in self.main or key in self.preemptive:
            return False
        self.stats.prefetches += 1
        self.attr.record_prefetch(cause, size)
        self.preemptive.put(key, _Entry(value, size, available_at, cause))
        if key not in self.preemptive:
            # too big for the preemptive budget: dropped on arrival
            self.attr.record_unused(cause, size)
        return True

    # -- writes & coherence ----------------------------------------------
    def write(self, key, value, size: int) -> None:
        """Write-through update: replace the value in place, treating the
        item as most recent (paper §4.4)."""
        self.stats.writes += 1
        if key in self.preemptive:
            # keep the attribution tag: presence is still owed to the
            # prefetch, even though the value was just superseded
            old = self.preemptive.peek(key)
            self.preemptive.put(key, _Entry(value, size, cause=old.cause))
            if key not in self.preemptive:
                self.attr.record_unused(old.cause, old.size)
        else:
            self.main.put(key, _Entry(value, size))

    def invalidate(self, key) -> None:
        """Coherence notification from the store-side monitor (another
        client wrote this item)."""
        old = self.preemptive.peek(key)
        if old is not None:
            self.attr.record_unused(old.cause, old.size)
        removed = self.main.remove(key) | self.preemptive.remove(key)
        if removed:
            self.stats.invalidations += 1
