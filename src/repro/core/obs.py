"""Palpascope observability layer: causal tracing, metrics, attribution.

Zero-dependency (stdlib + the simulation's own virtual clocks) and
off by default: every request-path hook goes through a module-level
:data:`NULL_TRACER` whose methods are constant-returning no-ops, so an
untraced run pays a handful of attribute lookups per op (gated in
``bench_overhead.py`` as ``tracing_overhead_ratio``).

Three instruments, one module:

* **Causal tracing** — a :class:`Span` tree per client op, threaded
  through coordinator routing, node RPCs, cache lookups, the decision
  engine, and background prefetch issue.  Spans are stamped with
  *virtual* time (the simulation's clocks, never the host's), carry a
  ``status`` (chaos-dropped RPCs are marked ``dropped``), and nest: a
  child's ``[start, end]`` interval always lies inside its parent's —
  :meth:`Tracer.end` closes any still-open interval at the maximum of
  its children, so the invariant holds even when a traced region exits
  through an exception (unavailability ``KeyError`` under chaos is a
  legal outcome, not a leak).  Completed traces land in a bounded ring
  buffer, exportable as JSON for ``tools/palpascope.py``.
* **Metrics registry** — typed counters / gauges and fixed-bucket
  latency histograms with deterministic p50/p99/p999, registered by
  constant name (palplint PALP301 rejects computed names inside
  ``src/repro/core``: metric/span names must be the ``SPAN_*`` /
  ``EVENT_*`` / ``METRIC_*`` constants below, which keeps label
  cardinality finite by construction).
* **Prefetch attribution** — every background fetch carries the
  :class:`PrefetchCause` (pattern root, pattern length, heuristic,
  confidence) that emitted it; the cache feeds an
  :class:`AttributionTable` recording per-pattern prefetched / hit /
  evicted-unused mass, so the benches can export ``attr_*`` keys and
  the sum of per-pattern hits provably equals the cache's
  ``prefetch_hits`` counter (pinned by a tier-1 test).

Sampling: ``Tracer(sample=1/N, seed=...)`` keeps a deterministic 1-in-N
subset of root spans — the selection is a pure function of ``(seed,
root ordinal)``, so two tracers with the same seed over the same
workload capture byte-identical traces (chaoscheck replays depend on
this).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from collections import deque
from typing import Any, Iterable, Optional, Sequence

__all__ = [
    "Span", "Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "percentile", "latency_percentiles",
    "PrefetchCause", "AttributionTable",
    "span_kind_breakdown", "critical_path",
]

# ---------------------------------------------------------------------------
# Registered name table (the constant table palplint PALP301 checks
# against: span/event/metric names in src/repro/core must be these
# constants — never f-strings or ad-hoc literals, so cardinality stays
# finite and palpascope can key breakdowns by a closed vocabulary).
# ---------------------------------------------------------------------------

# span kinds
SPAN_OP = "op"                        # one client read/write/read_many
SPAN_CACHE = "cache_lookup"
SPAN_DEMAND = "demand_fetch"
SPAN_DECISION = "decision"
SPAN_PREFETCH = "prefetch_issue"
SPAN_ROUTE = "route"                  # coordinator routing + retry loop
SPAN_RPC = "rpc"                      # one message onto a node's channel
SPAN_SERVICE = "service"              # node-side service interval
SPAN_WRITE = "write"                  # coordinator replicated write
SPAN_MEMBERSHIP = "membership_move"   # ring-change range transfer

# zero-duration events attached to the innermost open span
EVENT_HINT = "hint"
EVENT_SLOPPY = "sloppy_write"
EVENT_READ_REPAIR = "read_repair"
EVENT_QUORUM = "quorum"
EVENT_RETRY = "retry"
EVENT_CHAOS_DROP = "chaos_drop"
EVENT_CHAOS_DELAY = "chaos_delay"
EVENT_CHAOS_DUP = "chaos_dup"
EVENT_PROBE = "probe"
EVENT_SHED = "prefetch_shed"

# metric names (registry keys; benches snapshot these per phase)
METRIC_READ_LATENCY = "read_latency_s"
METRIC_OPS = "ops"
METRIC_PREFETCH_ISSUED = "prefetch_issued"
METRIC_PREFETCH_HITS = "prefetch_hits"
METRIC_RPC_TIMEOUTS = "rpc_timeouts"
METRIC_STALE_READS = "stale_reads"
METRIC_DEMAND_WAIT = "demand_wait_s"
METRIC_STORE_FETCHES = "store_fetches"
METRIC_SESSIONS = "sessions"
METRIC_PREFILL_S = "prefill_s"
METRIC_DECODE_S = "decode_s"
METRIC_TOKENS = "tokens"

REGISTERED_NAMES = frozenset(
    v for k, v in list(globals().items())
    if k.startswith(("SPAN_", "EVENT_", "METRIC_")) and isinstance(v, str)
)


# ---------------------------------------------------------------------------
# Spans + tracer
# ---------------------------------------------------------------------------


class Span:
    """One timed interval on the virtual clock.  ``fields`` and
    ``children`` are lazily allocated — an annotation-free span is three
    floats and two Nones."""

    __slots__ = ("kind", "start", "end", "status", "fields", "children")
    live = True

    def __init__(self, kind: str, start: float):
        self.kind = kind
        self.start = float(start)
        self.end: Optional[float] = None
        self.status = "ok"
        self.fields: Optional[dict] = None
        self.children: Optional[list] = None

    # -- annotation ------------------------------------------------------
    def set(self, **fields) -> "Span":
        if self.fields is None:
            self.fields = fields
        else:
            self.fields.update(fields)
        return self

    def mark(self, status: str) -> "Span":
        self.status = status
        return self

    def finish(self, t: float) -> "Span":
        self.end = float(t)
        return self

    def _attach(self, child: "Span") -> None:
        if self.children is None:
            self.children = [child]
        else:
            self.children.append(child)

    # -- queries ---------------------------------------------------------
    @property
    def duration(self) -> float:
        return (self.end if self.end is not None else self.start) - self.start

    def walk(self) -> Iterable["Span"]:
        yield self
        for c in self.children or ():
            yield from c.walk()

    def to_dict(self) -> dict:
        d: dict = {"kind": self.kind, "start": self.start,
                   "end": self.end if self.end is not None else self.start,
                   "status": self.status}
        if self.fields:
            d["fields"] = {k: _jsonable(v) for k, v in self.fields.items()}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


class _NullSpan(Span):
    """The do-nothing span singleton: every mutator is a constant-return
    no-op, so untraced hot paths cost one method call per hook."""

    __slots__ = ()
    live = False

    def __init__(self):
        super().__init__("null", 0.0)

    def set(self, **fields) -> "Span":
        return self

    def mark(self, status: str) -> "Span":
        return self

    def finish(self, t: float) -> "Span":
        return self

    def _attach(self, child: "Span") -> None:
        pass


NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: the default on every store and client.  All
    methods return :data:`NULL_SPAN` or do nothing."""

    active = False
    sample = 0.0

    def start(self, kind: str, t: float) -> Span:
        return NULL_SPAN

    def span(self, kind: str, t: float) -> Span:
        return NULL_SPAN

    def event(self, name: str, t: float, **fields) -> None:
        return None

    def end(self, span: Span, t: Optional[float] = None) -> None:
        return None


NULL_TRACER = NullTracer()


def _sample_hash(seed: int, n: int) -> float:
    """Deterministic uniform draw in [0, 1) for root ordinal ``n`` —
    blake2b, not ``hash()``, so the same seed selects the same traces
    across processes (CI -> laptop replays)."""
    h = hashlib.blake2b(f"{seed}|{n}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big") / 2.0 ** 64


class Tracer:
    """Collects span trees rooted at client/coordinator ops.

    Single-threaded by construction (the simulation is), so causal
    context is a plain stack: :meth:`span` nests under the innermost
    open span, :meth:`start` opens a root (or nests, when called inside
    an already-open trace — a store-level op under a client op).
    Completed traces land in a bounded ring buffer (``capacity``).
    """

    active = True

    def __init__(self, sample: float = 1.0, seed: int = 0,
                 capacity: int = 256):
        self.sample = float(sample)
        self.seed = int(seed)
        self.traces: deque = deque(maxlen=int(capacity))
        self.roots_seen = 0          # root candidates (sampling ordinal)
        self.roots_kept = 0
        self._stack: list[Span] = []

    # -- span lifecycle --------------------------------------------------
    def start(self, kind: str, t: float) -> Span:
        """Open a root span (sampled) or, mid-trace, a child span."""
        if self._stack:
            return self.span(kind, t)
        self.roots_seen += 1
        if self.sample < 1.0 and \
                _sample_hash(self.seed, self.roots_seen) >= self.sample:
            return NULL_SPAN
        self.roots_kept += 1
        sp = Span(kind, t)
        self._stack.append(sp)
        return sp

    def span(self, kind: str, t: float) -> Span:
        """Open a child of the innermost open span; no-op outside a
        sampled trace."""
        if not self._stack:
            return NULL_SPAN
        sp = Span(kind, t)
        self._stack[-1]._attach(sp)
        self._stack.append(sp)
        return sp

    def event(self, name: str, t: float, **fields) -> None:
        """Zero-duration annotation on the innermost open span."""
        if not self._stack:
            return
        ev = Span(name, t)
        ev.end = float(t)
        ev.status = "event"
        if fields:
            ev.fields = fields
        self._stack[-1]._attach(ev)

    def end(self, span: Span, t: Optional[float] = None) -> None:
        """Close ``span``: pop it, defaulting the end time to the latest
        child end (so exception exits still close every interval), and
        clamp it to cover its children (the nesting invariant)."""
        if span is NULL_SPAN or not self._stack:
            return
        top = self._stack.pop()
        # disciplined try/finally call sites keep this LIFO; a mismatch
        # would mean an unbalanced site, surfaced loudly in tests
        assert top is span, f"unbalanced span end: {span.kind} vs {top.kind}"
        end = span.end if t is None else float(t)
        floor = span.start
        for c in span.children or ():
            if c.end is not None and c.end > floor:
                floor = c.end
        span.end = floor if end is None else max(end, floor)
        if not self._stack:
            self.traces.append(span)

    # -- export ----------------------------------------------------------
    @property
    def open_spans(self) -> int:
        return len(self._stack)

    def export(self) -> dict:
        return {"sample": self.sample, "seed": self.seed,
                "roots_seen": self.roots_seen,
                "roots_kept": self.roots_kept,
                "traces": [t.to_dict() for t in self.traces]}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# Trace analysis (shared by tools/palpascope.py and the benches)
# ---------------------------------------------------------------------------


def _as_dict(span) -> dict:
    return span.to_dict() if isinstance(span, Span) else span


def span_kind_breakdown(traces: Sequence) -> dict[str, dict]:
    """Per-span-kind latency breakdown over exported trace dicts (or
    live Spans): count, total/mean virtual seconds, p50/p99."""
    by_kind: dict[str, list[float]] = {}
    def visit(d: dict) -> None:
        if d.get("status") != "event":
            by_kind.setdefault(d["kind"], []).append(
                d.get("end", d["start"]) - d["start"])
        for c in d.get("children", ()):
            visit(c)
    for t in traces:
        visit(_as_dict(t))
    out = {}
    for kind in sorted(by_kind):
        durs = by_kind[kind]
        out[kind] = {
            "count": len(durs),
            "total_s": sum(durs),
            "mean_s": sum(durs) / len(durs),
            "p50_s": percentile(durs, 50.0),
            "p99_s": percentile(durs, 99.0),
        }
    return out


def critical_path(trace) -> list[dict]:
    """The chain of spans that determines the root's completion time:
    from the root, repeatedly descend into the child whose end time
    matches the parent's frontier.  Returns one row per hop with the
    span's self time (its duration minus the part explained by the
    next hop)."""
    node = _as_dict(trace)
    path = []
    while True:
        end = node.get("end", node["start"])
        kids = [c for c in node.get("children", ())
                if c.get("status") != "event"]
        nxt = None
        for c in kids:
            ce = c.get("end", c["start"])
            if nxt is None or ce > nxt.get("end", nxt["start"]):
                nxt = c
        dur = end - node["start"]
        child_dur = (nxt.get("end", nxt["start"]) - nxt["start"]
                     if nxt is not None else 0.0)
        path.append({
            "kind": node["kind"], "status": node.get("status", "ok"),
            "start": node["start"], "end": end,
            "duration_s": dur, "self_s": max(0.0, dur - child_dur),
            "fields": node.get("fields", {}),
        })
        if nxt is None:
            return path
        node = nxt


# ---------------------------------------------------------------------------
# Percentiles + histograms
# ---------------------------------------------------------------------------


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (the one canonical definition all benches
    share; ``bench_cluster`` and ``bench_overhead`` used to disagree on
    interpolation).  ``q`` in [0, 100]."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q!r} outside [0, 100]")
    vs = sorted(values)
    if not vs:
        return 0.0
    rank = math.ceil(q / 100.0 * len(vs))
    return float(vs[max(0, rank - 1)])


def latency_percentiles(values: Sequence[float]) -> dict[str, float]:
    """The standard p50/p99/p999 triple, nearest-rank."""
    vs = sorted(values)
    if not vs:
        return {"p50": 0.0, "p99": 0.0, "p999": 0.0}
    def at(q: float) -> float:
        return float(vs[max(0, math.ceil(q / 100.0 * len(vs)) - 1)])
    return {"p50": at(50.0), "p99": at(99.0), "p999": at(99.9)}


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


def default_latency_buckets() -> list[float]:
    """96 log-spaced bucket upper bounds, 1 µs to ~40 s (ratio 1.2):
    fine enough that a bucketed p99 lands within ~20 % of exact, fixed
    so histograms from different phases/runs are mergeable."""
    return [1e-6 * 1.2 ** i for i in range(96)]


class Histogram:
    """Fixed-bucket latency histogram over virtual seconds.

    Bucketed percentiles are deterministic (they return the upper bound
    of the bucket holding the nearest-rank sample — never an
    interpolated value two runs could disagree on) and mergeable across
    phases.  Exact sample-level percentiles are :func:`percentile`'s
    job; the regression test pins both on a known sample.
    """

    __slots__ = ("name", "bounds", "counts", "count", "total", "vmax")

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds = list(bounds) if bounds is not None \
            else default_latency_buckets()
        if sorted(self.bounds) != self.bounds:
            raise ValueError("histogram bounds must be sorted")
        self.counts = [0] * (len(self.bounds) + 1)  # +1 overflow bucket
        self.count = 0
        self.total = 0.0
        self.vmax = 0.0

    def record(self, v: float) -> None:
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    def percentile(self, q: float) -> float:
        """Upper bound of the bucket containing the nearest-rank sample
        (the overflow bucket reports the observed max)."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self.bounds[i] if i < len(self.bounds) else self.vmax
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean, "max": self.vmax,
                "p50": self.percentile(50.0), "p99": self.percentile(99.0),
                "p999": self.percentile(99.9)}


class MetricsRegistry:
    """Typed metrics registered by constant name.  Re-registering a name
    returns the existing instrument; registering it as a different type
    is an error (one name, one meaning)."""

    def __init__(self):
        self._metrics: dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args)
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Histogram(name, bounds)
        elif not isinstance(m, Histogram):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not Histogram")
        return m

    def snapshot(self) -> dict:
        """One dict per bench phase: counters/gauges flatten to values,
        histograms to their percentile snapshots."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        self._metrics.clear()


# ---------------------------------------------------------------------------
# Prefetch attribution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrefetchCause:
    """Why a background fetch was issued: the probabilistic tree (named
    by its root container key), the pattern length (depth of the
    predicted node — the length of the confirmed prefix that predicted
    it), the heuristic, and the node's cumulative confidence."""

    root: Any              # the tree's root container key (or item id)
    length: int            # predicted node depth == pattern prefix length
    heuristic: str
    confidence: float = 0.0

    def group_key(self) -> tuple:
        """Aggregation key: confidence is a per-fetch sample, not part
        of the pattern's identity."""
        return (self.heuristic, self.root, self.length)


_UNATTRIBUTED = ("unattributed", None, 0)


@dataclasses.dataclass
class AttributionRow:
    prefetched: int = 0          # admitted background fetches
    hits: int = 0                # first-touch prefetch hits
    unused: int = 0              # evicted/invalidated/raced, never touched
    bytes_prefetched: int = 0
    bytes_hit: int = 0
    bytes_unused: int = 0
    confidence_sum: float = 0.0  # over prefetched (mean = sum/prefetched)


class AttributionTable:
    """Per-pattern prefetch accounting, fed by the two-space cache.

    Conservation: every admitted prefetch is either eventually *hit*
    (first touch), recorded *unused* on its way out (evicted from the
    preemptive space, invalidated, or raced by a demand fetch), or
    still resident.  Summing ``hits`` over rows equals the cache's
    ``prefetch_hits`` counter exactly — the tier-1 test pins this.
    """

    __slots__ = ("rows",)

    def __init__(self):
        self.rows: dict[tuple, AttributionRow] = {}

    def _row(self, cause: Optional[PrefetchCause]) -> AttributionRow:
        key = cause.group_key() if cause is not None else _UNATTRIBUTED
        row = self.rows.get(key)
        if row is None:
            row = self.rows[key] = AttributionRow()
        return row

    def record_prefetch(self, cause: Optional[PrefetchCause],
                        size: int) -> None:
        row = self._row(cause)
        row.prefetched += 1
        row.bytes_prefetched += int(size)
        if cause is not None:
            row.confidence_sum += cause.confidence

    def record_hit(self, cause: Optional[PrefetchCause], size: int) -> None:
        row = self._row(cause)
        row.hits += 1
        row.bytes_hit += int(size)

    def record_unused(self, cause: Optional[PrefetchCause],
                      size: int) -> None:
        row = self._row(cause)
        row.unused += 1
        row.bytes_unused += int(size)

    # -- aggregation -----------------------------------------------------
    def merge(self, other: "AttributionTable") -> "AttributionTable":
        for key, r in other.rows.items():
            mine = self.rows.get(key)
            if mine is None:
                mine = self.rows[key] = AttributionRow()
            for f in dataclasses.fields(AttributionRow):
                setattr(mine, f.name,
                        getattr(mine, f.name) + getattr(r, f.name))
        return self

    @staticmethod
    def merged(tables: Iterable["AttributionTable"]) -> "AttributionTable":
        out = AttributionTable()
        for t in tables:
            out.merge(t)
        return out

    # -- roll-ups --------------------------------------------------------
    @property
    def total_hits(self) -> int:
        return sum(r.hits for r in self.rows.values())

    @property
    def total_prefetched(self) -> int:
        return sum(r.prefetched for r in self.rows.values())

    @property
    def waste_ratio(self) -> float:
        """Unused mass over prefetched mass (bytes) — the efficiency
        complement of precision, by pattern-attributable bytes."""
        pre = sum(r.bytes_prefetched for r in self.rows.values())
        return (sum(r.bytes_unused for r in self.rows.values()) / pre
                if pre else 0.0)

    def hit_mass_by_length_decile(self, max_len: int = 15) -> list[float]:
        """Hit byte-mass bucketed into 10 pattern-length deciles of
        ``[1, max_len]`` — MITHRIL's question ("which signal source
        earns its prefetches?") asked of pattern length."""
        out = [0.0] * 10
        for (_h, _root, length), r in self.rows.items():
            d = min(9, max(0, (max(1, int(length)) - 1) * 10 // max_len))
            out[d] += r.bytes_hit
        return out

    def top_rows(self, n: int = 5) -> list[dict]:
        """The n patterns with the most hit mass (ties: most prefetched),
        as plain dicts for JSON export / step summaries."""
        keyed = sorted(
            self.rows.items(),
            key=lambda kv: (-kv[1].bytes_hit, -kv[1].prefetched,
                            repr(kv[0])))
        out = []
        for (heur, root, length), r in keyed[:n]:
            out.append({
                "heuristic": heur, "root": _jsonable(root),
                "length": length, "prefetched": r.prefetched,
                "hits": r.hits, "unused": r.unused,
                "bytes_hit": r.bytes_hit,
                "mean_confidence": (r.confidence_sum / r.prefetched
                                    if r.prefetched else 0.0),
            })
        return out
