"""Request capture and sessionization (Palpatine §3.1, "Data pre-processing").

Palpatine intercepts read requests at the client library and builds its own
structured backlog: a *sequence database* of user sessions.  A session is a
burst of activity — consecutive requests separated by less than a time gap.
An item is a *data container*: the metadata identifying a cell in the back
store (table, row, column family:qualifier, or any combination).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

__all__ = [
    "Container",
    "AccessLogger",
    "SequenceDatabase",
]


@dataclasses.dataclass(frozen=True, order=True)
class Container:
    """A data container: identifies a cell (or slice) of the back store.

    Any component may be ``None`` — e.g. a frequent *column* sequence for the
    same row uses containers that only differ in ``column`` (paper §3.1
    pattern type 1), a frequent *row* sequence only in ``row`` (type 2), and
    hybrid sequences vary both (type 3).
    """

    table: Optional[str] = None
    row: Optional[str] = None
    column: Optional[str] = None  # "family:qualifier"

    def key(self) -> tuple:
        return (self.table, self.row, self.column)

    def __str__(self) -> str:  # compact log form
        return f"{self.table or '*'}/{self.row or '*'}/{self.column or '*'}"


class SequenceDatabase:
    """An integer-encoded sequence database over a container vocabulary.

    Sessions are tuples of item ids.  The database owns the id<->container
    vocabulary and lazily materializes the padded matrix / packed vertical
    bitmaps used by the miners.
    """

    def __init__(self) -> None:
        self._vocab: dict = {}
        self._items: list = []
        self.sessions: list[tuple[int, ...]] = []

    # -- vocabulary ---------------------------------------------------------
    def item_id(self, container) -> int:
        key = container.key() if isinstance(container, Container) else container
        iid = self._vocab.get(key)
        if iid is None:
            iid = len(self._items)
            self._vocab[key] = iid
            self._items.append(key)
        return iid

    def item(self, iid: int):
        return self._items[iid]

    @property
    def n_items(self) -> int:
        return len(self._items)

    # -- key-space translation (pattern exchange between clients) ----------
    def decode(self, item_ids: Iterable[int]) -> tuple:
        """Translate item ids to container keys — vocabulary-independent
        form, so a pattern can leave this client (gossip, persistence)."""
        return tuple(self._items[i] for i in item_ids)

    def encode(self, keys: Iterable) -> tuple:
        """Translate container keys to this database's item ids, growing
        the vocabulary for keys not seen locally yet."""
        return tuple(self.item_id(k) for k in keys)

    def __len__(self) -> int:
        return len(self.sessions)

    # -- construction -------------------------------------------------------
    def add_session(self, containers: Iterable) -> None:
        seq = tuple(self.item_id(c) for c in containers)
        if seq:
            self.sessions.append(seq)

    @classmethod
    def from_sessions(cls, sessions: Iterable[Sequence]) -> "SequenceDatabase":
        db = cls()
        for s in sessions:
            db.add_session(s)
        return db

    # -- dense views for the miners ----------------------------------------
    def padded_matrix(self) -> tuple[np.ndarray, np.ndarray]:
        """Return ``(mat, lengths)``; ``mat`` is (n_sessions, max_len) int32,
        padded with -1."""
        if not self.sessions:
            return np.zeros((0, 0), np.int32), np.zeros((0,), np.int32)
        lengths = np.array([len(s) for s in self.sessions], np.int32)
        mat = np.full((len(self.sessions), int(lengths.max())), -1, np.int32)
        for i, s in enumerate(self.sessions):
            mat[i, : len(s)] = s
        return mat, lengths

    def tail(self, n_sessions: int) -> "SequenceDatabase":
        """A view database over the most recent ``n_sessions`` (online mining
        works on the chunk of the backlog since the last mining run)."""
        db = SequenceDatabase()
        db._vocab, db._items = self._vocab, self._items  # share vocab
        db.sessions = self.sessions[-n_sessions:]
        return db


class AccessLogger:
    """Monitoring component: appends intercepted reads to the backlog and
    cuts sessions on time gaps (paper §3.1).

    ``session_gap`` is in the same (virtual) time unit the caller uses.
    """

    def __init__(self, session_gap: float = 1.0) -> None:
        self.session_gap = float(session_gap)
        self.db = SequenceDatabase()
        self._open: list = []
        self._last_t: Optional[float] = None
        self.n_events = 0

    def record(self, t: float, container) -> None:
        if self._last_t is not None and (t - self._last_t) > self.session_gap:
            self.flush_session()
        self._open.append(container)
        self._last_t = t
        self.n_events += 1

    def record_many(self, t: float, containers: Iterable) -> None:
        """Log a batch issued as overlapping in-flight reads: one burst at
        a single virtual timestamp.  The batch order is preserved in the
        session (mining sees the same sequence a loop of ``record`` calls
        would produce), and a batch never straddles a session cut."""
        for c in containers:
            self.record(t, c)

    def flush_session(self) -> None:
        if self._open:
            self.db.add_session(self._open)
            self._open = []

    def snapshot(self) -> SequenceDatabase:
        """Close the open session and return the backlog database."""
        self.flush_session()
        return self.db

    def reset_backlog(self) -> None:
        """Drop logged sessions (after a mining run consumed them) but keep
        the vocabulary, so pattern ids stay stable across mining runs."""
        self.flush_session()
        db = SequenceDatabase()
        db._vocab, db._items = self.db._vocab, self.db._items
        self.db = db
