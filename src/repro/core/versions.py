"""Dotted version vectors for partition-tolerant causality.

The cluster's original versioning scheme was a single monotone counter
per coordinator: good enough while exactly one coordinator stamps every
write, silently wrong the moment two coordinators write the same key on
opposite sides of a partition (both mint the same integer, the heal sees
"equal versions", and one acked write is dropped without a trace).

A :class:`DottedVersion` fixes that with the classic dotted-version-
vector construction:

* the **dot** is this write's unique event id — ``(counter, coord)``
  where ``counter`` is the stamping coordinator's monotone write counter
  and ``coord`` its integer id;
* the **clock** is the causal context the coordinator observed when it
  stamped the write — a pointwise-max map ``coord -> counter`` over the
  versions visible on the replicas the write will land on.

``a.descends(b)`` iff ``b``'s dot is inside ``a``'s causal history;
two versions where neither descends the other are **siblings**
(concurrent writes), and :func:`merge` resolves them deterministically:
last-writer-wins **by dot** (highest ``(counter, coord)`` pair picks the
surviving value) while the merged clock keeps *every* dot, so neither
write is silently forgotten — the loser is recorded as superseded, not
lost.

Interop contract: the rest of the repo still compares versions with
``<``/``<=``/``max`` and uses ``0`` for "absent".  Plain ints therefore
remain valid versions (legacy ``versioning='counter'`` mode and
hand-written tests) and order against dotted versions through the same
sort key — an int ``n`` behaves as the dot ``(n, -1)`` with an empty
clock, which every real coordinator dot (coord id >= 0) beats on ties.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Tuple, Union

Version = Union[int, "DottedVersion"]

# sort key of a plain-int legacy version n: dot (n, coord=-1), empty clock
_LEGACY_COORD = -1


def sort_key(v: Version) -> Tuple[int, int, Tuple[Tuple[int, int], ...]]:
    """Total order over versions: (counter, coord, clock) lexicographic.

    The first two components are the dot — so last-writer-wins-by-dot is
    literally ``max(versions, key=sort_key)`` — and the clock breaks the
    residual tie between "same dot, smaller context" states that read
    repair creates while it is upgrading a winner's clock in place.
    """
    if isinstance(v, DottedVersion):
        return (v.dot[0], v.dot[1], v.clock)
    return (int(v), _LEGACY_COORD, ())


@dataclass(frozen=True)
class DottedVersion:
    """One write event: a dot ``(counter, coord)`` plus its causal clock.

    ``clock`` is stored as a sorted tuple of ``(coord, counter)`` pairs so
    the value is immutable, hashable, and has a canonical repr (the chaos
    fingerprint hashes it byte-for-byte).
    """

    dot: Tuple[int, int]  # (counter, coord)
    clock: Tuple[Tuple[int, int], ...] = ()

    @staticmethod
    def stamp(coord: int, counter: int,
              context: Iterable[Version] = ()) -> "DottedVersion":
        """Mint the version for a new write by coordinator ``coord``.

        ``context`` is whatever versions the coordinator could *see* on
        the replicas it is about to write: the new clock is their
        pointwise max plus this write's own dot.  Writes stamped on
        opposite sides of a partition see disjoint contexts and come out
        as siblings; sequential writes see each other and chain.
        """
        merged: dict[int, int] = {}
        for v in context:
            if isinstance(v, DottedVersion):
                for c, n in v.clock:
                    if n > merged.get(c, 0):
                        merged[c] = n
                dc, dn = v.dot[1], v.dot[0]
                if dn > merged.get(dc, 0):
                    merged[dc] = dn
            elif int(v) > merged.get(_LEGACY_COORD, 0):
                merged[_LEGACY_COORD] = int(v)
        if counter > merged.get(coord, 0):
            merged[coord] = counter
        return DottedVersion(
            dot=(counter, coord),
            clock=tuple(sorted(merged.items())),
        )

    def seen(self, counter: int, coord: int) -> bool:
        """Is the event ``(counter, coord)`` inside this causal history?"""
        if coord == self.dot[1] and counter <= self.dot[0]:
            return True
        for c, n in self.clock:
            if c == coord:
                return counter <= n
        return False

    def counter_of(self, coord: int) -> int:
        """Highest ``coord`` counter inside this causal history (0 if
        none) — what a restarting coordinator resumes its dot counter
        past, so dots stay unique across crash-restarts."""
        n = self.dot[0] if self.dot[1] == coord else 0
        for c, m in self.clock:
            if c == coord and m > n:
                n = m
        return n

    def descends(self, other: Version) -> bool:
        """True iff ``other`` is in this version's causal past (or equal)."""
        if isinstance(other, DottedVersion):
            return self.seen(other.dot[0], other.dot[1])
        # legacy int: 0 is "absent" (everything descends it); a hand-set
        # positive int orders by the interop sort key
        return int(other) <= 0 or sort_key(self) >= sort_key(other)

    # rich comparisons over the total sort key keep every pre-existing
    # `ver <= node.versions.get(k, 0)` / `max(vers)` call site working
    # unchanged when versions become dotted
    def __lt__(self, other: Version) -> bool:
        return sort_key(self) < sort_key(other)

    def __le__(self, other: Version) -> bool:
        return sort_key(self) <= sort_key(other)

    def __gt__(self, other: Version) -> bool:
        return sort_key(self) > sort_key(other)

    def __ge__(self, other: Version) -> bool:
        return sort_key(self) >= sort_key(other)


def descends(a: Version, b: Version) -> bool:
    """Causality check that tolerates legacy int versions on either side."""
    if isinstance(a, DottedVersion):
        return a.descends(b)
    if isinstance(b, DottedVersion):
        # a plain int never truly saw a dotted write; order by sort key so
        # counter-mode clusters keep their old monotone behaviour
        return sort_key(a) >= sort_key(b)
    return int(a) >= int(b)


def concurrent(a: Version, b: Version) -> bool:
    """Siblings: neither version descends the other."""
    return not descends(a, b) and not descends(b, a)


def merge(versions: Iterable[Version]) -> Version:
    """Deterministic sibling resolution: last-writer-wins **by dot**.

    The surviving dot is the max sort key; the merged clock is the
    pointwise max over every participant's clock *and* dot, so each
    sibling's event stays inside the survivor's causal history (that is
    what lets the invariant checker prove "no acked write silently
    lost": its dot must appear in the final clock).
    """
    vs = list(versions)
    if not vs:
        return 0
    winner = max(vs, key=sort_key)
    if not isinstance(winner, DottedVersion):
        return winner
    merged: dict[int, int] = {}
    for v in vs:
        if isinstance(v, DottedVersion):
            for c, n in v.clock:
                if n > merged.get(c, 0):
                    merged[c] = n
            dn, dc = v.dot
            if dn > merged.get(dc, 0):
                merged[dc] = dn
        elif int(v) > merged.get(_LEGACY_COORD, 0):
            merged[_LEGACY_COORD] = int(v)
    return DottedVersion(dot=winner.dot, clock=tuple(sorted(merged.items())))
