"""Sequential pattern mining (Palpatine §3.2).

Implements the algorithm families the paper compares, over a shared packed
vertical-bitmap engine (the SPAM/VMSP representation):

* ``gsp``        — Apriori, breadth-first candidate generation.
* ``spam``       — Apriori over vertical bitmaps (all patterns).
* ``prefixspan`` — pattern-growth, depth-first projected databases.
* ``vmsp``       — the paper's choice: vertical bitmaps + *maximal* filtering.

Palpatine's configuration (paper §3.2/§5): single-item itemsets (an access
log is totally ordered), ``maxgap=1`` (consecutive pattern items must be
adjacent in the session), pattern length in [3, 15], dynamic minimum support.

Bitmaps are materialized for *frequent items only* (item support is counted
from the padded session matrix first), so memory is O(freq_items × sessions ×
words) — the back store may hold millions of containers but only the hot set
enters the vertical representation.

Frontier engine
---------------
The bitmap miners (``gsp``/``spam``/``vmsp``) walk the pattern lattice
*level-synchronously*: all surviving depth-``d`` prefixes are held as one
packed ``(P, S, W)`` uint32 tensor and the whole frontier is expanded in a
single fused ``(P, K)`` join against the candidate item bitmaps.  Extension
slots are computed once per level (not per candidate batch), support counting
visits only the sessions where a prefix actually occurs (the slot tensor is
~``support/S`` dense at low minsup), and joined bitmaps are materialized only
for the surviving ``(prefix, item)`` pairs.  Forward-extension maximality for
VMSP is a per-prefix boolean mask over the ``(P, K)`` support matrix.

``MiningParams.frontier_budget`` caps the transient join tensor in bytes:
oversized frontiers are processed in budget-sized slabs, and a walk whose
*single-prefix* ``K×S×W`` join already exceeds the cap (a walk-invariant
quantity) spills entirely to the legacy per-node DFS walker (``_dfs_mine``),
which remains the reference implementation for differential tests.

With ``use_kernel=True`` the fused join runs on the Pallas TPU kernel
``frontier_join_support`` in :mod:`repro.kernels.bitmap_support` (validated
in interpret mode on CPU); the DFS spill path uses the per-prefix
``sstep_join`` kernel.

Incremental dynamic minsup
--------------------------
``mine_dynamic_minsup`` builds the packed ``VerticalBitmaps`` **once** at the
floor support and re-thresholds per decay retry instead of re-scattering the
session matrix per minsup step; callers that re-mine an unchanged backlog
(``PalpatineClient.mine_now``) can pass a cached ``vb`` to skip the build
entirely.  A prebuilt ``vb`` must have been constructed at a support count
no higher than the one mined at — rows below the current threshold are
filtered inside the engine.
"""

from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Callable, Optional, Sequence

import numpy as np

from .sessions import SequenceDatabase

__all__ = [
    "MiningParams",
    "Pattern",
    "VerticalBitmaps",
    "BITMAP_ALGOS",
    "mine",
    "gsp",
    "spam",
    "prefixspan",
    "vmsp",
    "maximal_filter",
    "mine_dynamic_minsup",
    "dynamic_floor_count",
    "brute_force",
]

_WORD = 32  # packed uint32 words

#: byte cap on the boolean (n_sessions × n_items) dedup scratch in
#: VerticalBitmaps.__init__; larger databases fall back to row-local sorts
_SCATTER_BUDGET_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class MiningParams:
    """User-specific constraints (paper §3.2 / §5 'Pattern mining')."""

    minsup: float = 0.1          # fraction of sessions
    min_len: int = 3
    max_len: int = 15
    maxgap: Optional[int] = 1    # 1 = contiguous (paper default); None = any
    use_kernel: bool = False     # route support counting through Pallas
    # byte cap on the frontier engine's transient join tensor; a walk whose
    # single-prefix K×S×W join exceeds it falls back to the DFS walker
    frontier_budget: int = 64 * 1024 * 1024

    def minsup_count(self, n_sessions: int) -> int:
        return max(1, int(math.ceil(self.minsup * n_sessions)))


@dataclasses.dataclass(frozen=True)
class Pattern:
    items: tuple
    support: int

    def __len__(self) -> int:
        return len(self.items)


# ---------------------------------------------------------------------------
# Vertical packed-bitmap engine (SPAM / VMSP representation)
# ---------------------------------------------------------------------------


class VerticalBitmaps:
    """Per-item occurrence bitmaps for the frequent items, packed 32
    positions/word.

    ``bits[r]`` has shape (n_sessions, n_words); bit ``p % 32`` of word
    ``p // 32`` for session ``s`` is set iff item ``freq_items[r]`` occurs at
    position ``p`` of session ``s``.  Padding positions are never set, so
    joining with an item bitmap implicitly masks shifted-past-the-end bits.
    """

    def __init__(self, db: SequenceDatabase, minsup_count: int = 1):
        mat, _ = db.padded_matrix()
        self.n_sessions = mat.shape[0]
        max_len = mat.shape[1] if mat.size else 0
        self.n_words = max(1, (max_len + _WORD - 1) // _WORD)

        if mat.size:
            sess, pos = np.nonzero(mat >= 0)
            item = mat[sess, pos]
            # item support = #sessions containing the item (count each
            # (sess, item) pair once).  Two dedup strategies replace the
            # global np.unique-over-encoded-pairs sort: a sort-free boolean
            # scatter when the (n_sessions × n_items) scratch fits the byte
            # budget, else per-row sorts of the (short) padded matrix —
            # n_items is the *cumulative* vocabulary (tail() views share
            # it), so the dense scratch must not scale with it unchecked.
            if self.n_sessions * db.n_items <= _SCATTER_BUDGET_BYTES:
                seen = np.zeros((self.n_sessions, db.n_items), bool)
                seen[sess, item] = True
                per_item = seen.sum(axis=0, dtype=np.int64)
            else:
                sm = np.sort(mat, axis=1)          # row-local: dups adjacent
                keep = sm >= 0                     # drop -1 padding
                keep[:, 1:] &= sm[:, 1:] != sm[:, :-1]
                per_item = np.bincount(
                    sm[keep], minlength=db.n_items
                ).astype(np.int64)
            self.freq_items = np.nonzero(per_item >= minsup_count)[0].astype(np.int32)
            self.freq_support = per_item[self.freq_items]
            row_of = np.full(db.n_items, -1, np.int32)
            row_of[self.freq_items] = np.arange(self.freq_items.size, dtype=np.int32)
            keep = row_of[item] >= 0
            sess, pos, item = sess[keep], pos[keep], item[keep]
            bits = np.zeros(
                (self.freq_items.size, self.n_sessions, self.n_words), np.uint32
            )
            word, bit = pos // _WORD, pos % _WORD
            np.bitwise_or.at(
                bits,
                (row_of[item], sess, word),
                (np.uint32(1) << bit.astype(np.uint32)),
            )
            self._row_of = row_of
        else:
            self.freq_items = np.zeros((0,), np.int32)
            self.freq_support = np.zeros((0,), np.int64)
            self._row_of = np.full(db.n_items, -1, np.int32)
            bits = np.zeros((0, self.n_sessions, self.n_words), np.uint32)
        self.bits = bits

    def row(self, item_id: int) -> int:
        r = int(self._row_of[item_id])
        if r < 0:
            raise KeyError(f"item {item_id} is not frequent")
        return r

    # -- primitive ops ------------------------------------------------------
    @staticmethod
    def shift1(b: np.ndarray) -> np.ndarray:
        """Move every set bit one position later (possible extension slots
        for maxgap=1).  Works on (..., n_words)."""
        carry = np.zeros_like(b)
        carry[..., 1:] = b[..., :-1] >> np.uint32(31)
        return ((b << np.uint32(1)) | carry).astype(np.uint32)

    @classmethod
    def smear_after(cls, b: np.ndarray) -> np.ndarray:
        """Set all positions strictly after the first set bit per session
        (SPAM's s-step transform for unconstrained gap)."""
        x = b.copy()
        for k in (1, 2, 4, 8, 16):  # within-word smear toward higher bits
            x |= x << np.uint32(k)
        after = cls.shift1(x)
        # any earlier word nonzero -> whole word saturates
        nz = (b != 0).astype(np.uint32)
        earlier = np.cumsum(nz, axis=-1) - nz  # count of nonzero earlier words
        after[earlier > 0] = np.uint32(0xFFFFFFFF)
        return after

    def extension_slots(self, b: np.ndarray, maxgap: Optional[int]) -> np.ndarray:
        if maxgap is None:
            return self.smear_after(b)
        out = self.shift1(b)
        acc = out
        for _ in range(maxgap - 1):
            acc = self.shift1(acc)
            out = out | acc
        return out

    @staticmethod
    def support(b: np.ndarray) -> np.ndarray:
        """#sessions with >=1 set bit.  (..., S, W) -> (...,)."""
        return np.any(b != 0, axis=-1).sum(axis=-1)

    # -- batched s-step join (per-prefix; used by the DFS spill path) -------
    def sstep_join(
        self,
        prefix_bits: np.ndarray,
        cand_rows: np.ndarray,
        maxgap: Optional[int],
        use_kernel: bool = False,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Join a prefix bitmap against candidate item bitmaps (by row).

        Returns ``(joined (K,S,W), support (K,))`` where ``joined[k]`` marks
        end positions of ``prefix + (freq_items[cand_rows[k]],)``.
        """
        slots = self.extension_slots(prefix_bits, maxgap)
        cand = self.bits[cand_rows]
        if use_kernel:
            from repro.kernels.bitmap_support import ops as _ops

            joined, sup = _ops.sstep_join_support(slots, cand)
            return np.asarray(joined), np.asarray(sup)
        joined = slots[None, :, :] & cand
        return joined, self.support(joined)


# ---------------------------------------------------------------------------
# Frontier engine — level-synchronous lattice walk, fused (P×K) support join
# ---------------------------------------------------------------------------


def _frontier_support(
    slots: np.ndarray,
    cand: np.ndarray,
    params: MiningParams,
    allowed: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Fused support count for a whole frontier: (P,S,W) × (K,S,W) -> (P,K).

    The numpy path is sparse over sessions: only ``(prefix, session)`` pairs
    with a nonzero slot word are joined (a prefix's slot tensor is
    ~``support/S`` dense, so this skips the vast majority of the dense
    ``P×K×S×W`` work at low minsup).  Chunked so the transient stays under
    ``params.frontier_budget`` bytes.  ``use_kernel=True`` routes the dense
    join through the Pallas ``frontier_join_support`` kernel instead.

    ``allowed`` is an optional (P,K) bool mask of candidate extensions per
    prefix (apriori narrowing for maxgap=None: a child's frequent
    extensions are a subset of its parent's).  The numpy path joins only
    the column union of the mask — items no prefix still allows drop out
    of the whole level — and disallowed pairs report support 0; the kernel
    path computes the dense join and masks after.
    """
    p_prefixes, n_sessions, n_words = slots.shape
    k_items = cand.shape[0]
    if p_prefixes == 0 or k_items == 0:
        return np.zeros((p_prefixes, k_items), np.int64)
    if params.use_kernel:
        from repro.kernels.bitmap_support import ops as _ops

        sup = np.asarray(_ops.frontier_join_support(slots, cand)).astype(np.int64)
        if allowed is not None:
            sup[~allowed] = 0
        return sup

    cols = None
    cand_cols = cand
    if allowed is not None:
        cols = np.nonzero(allowed.any(axis=0))[0]
        if cols.size == k_items:
            cols = None
        else:
            cand_cols = cand[cols]
    k_cols = cand_cols.shape[0]
    sup = np.zeros((p_prefixes, k_items), np.int64)
    pnz, snz = np.nonzero(slots.any(axis=-1))
    if pnz.size == 0 or k_cols == 0:
        return sup
    cand_t = np.ascontiguousarray(cand_cols.transpose(1, 0, 2))  # (S, Kc, W)
    chunk = max(1, int(params.frontier_budget) // (k_cols * n_words * 4))
    sup_view = sup if cols is None else np.zeros(
        (p_prefixes, k_cols), np.int64)
    for i in range(0, pnz.size, chunk):
        p_i, s_i = pnz[i : i + chunk], snz[i : i + chunk]
        sl = slots[p_i, s_i]                                 # (c, W)
        hit = ((sl[:, None, :] & cand_t[s_i]) != 0).any(-1)  # (c, Kc)
        # pnz is sorted, so equal-prefix entries form contiguous runs:
        # segment-reduce instead of scatter-add
        uniq, starts = np.unique(p_i, return_index=True)
        sup_view[uniq] += np.add.reduceat(hit.astype(np.int64), starts, axis=0)
    if cols is not None:
        sup[:, cols] = sup_view
    if allowed is not None:
        sup[~allowed] = 0
    return sup


def _dfs_expand(
    vb: VerticalBitmaps,
    params: MiningParams,
    msc: int,
    cand_rows: np.ndarray,
    cand_items: np.ndarray,
    pattern: tuple,
    pbits: np.ndarray,
    sup: int,
    maximal_only: bool,
    out: list,
) -> None:
    """Legacy per-node DFS from one lattice node (reference implementation;
    also the spill target when a frontier level exceeds the byte budget)."""
    has_freq_ext = False
    if len(pattern) < params.max_len and cand_rows.size:
        joined, sups = vb.sstep_join(pbits, cand_rows, params.maxgap, params.use_kernel)
        for k in np.nonzero(sups >= msc)[0]:
            has_freq_ext = True
            _dfs_expand(
                vb, params, msc, cand_rows, cand_items,
                pattern + (int(cand_items[k]),), joined[k], int(sups[k]),
                maximal_only, out,
            )
    if len(pattern) >= params.min_len and (not maximal_only or not has_freq_ext):
        out.append(Pattern(pattern, int(sup)))


def _dfs_mine(
    db: SequenceDatabase,
    params: MiningParams,
    maximal_only: bool,
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    """Per-node DFS lattice walk (the pre-frontier engine, kept as the
    differential reference and the budget-spill fallback)."""
    msc = params.minsup_count(len(db))
    if vb is None:
        vb = VerticalBitmaps(db, msc)
    rows = np.nonzero(vb.freq_support >= msc)[0]
    cand_items = vb.freq_items[rows]
    out: list[Pattern] = []
    for i, r in enumerate(rows):
        _dfs_expand(
            vb, params, msc, rows, cand_items,
            (int(cand_items[i]),), vb.bits[r], int(vb.freq_support[r]),
            maximal_only, out,
        )
    return out


def _frontier_mine(
    db: SequenceDatabase,
    params: MiningParams,
    maximal_only: bool,
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    """Level-synchronous frontier miner (see module docstring).

    Byte-identical Pattern output to :func:`_dfs_mine` (set-wise; emission
    order is per-level instead of depth-first)."""
    msc = params.minsup_count(len(db))
    if vb is None:
        vb = VerticalBitmaps(db, msc)
    rows = np.nonzero(vb.freq_support >= msc)[0]
    out: list[Pattern] = []
    if rows.size == 0:
        return out

    cand = vb.bits[rows]                      # (K, S, W), fixed for the walk
    cand_items = vb.freq_items[rows]
    k_items = rows.size
    per_prefix_bytes = k_items * vb.n_sessions * vb.n_words * 4
    if per_prefix_bytes > params.frontier_budget:
        # even a single prefix's K×S×W join exceeds the byte cap (the
        # quantity is walk-invariant, so this is a whole-walk decision):
        # fall back to the per-node DFS walker
        for i, r in enumerate(rows):
            _dfs_expand(
                vb, params, msc, rows, cand_items,
                (int(cand_items[i]),), vb.bits[r], int(vb.freq_support[r]),
                maximal_only, out,
            )
        return out

    patterns: list[tuple] = [(int(it),) for it in cand_items]
    fbits = cand                              # depth-1 frontier = item bitmaps
    fsups = vb.freq_support[rows].astype(np.int64)
    # per-branch candidate narrowing: for unconstrained gap a child's
    # frequent extensions are a subset of its parent's (dropping the last
    # prefix item keeps any occurrence a subsequence), so each frontier
    # entry only joins against its parent's surviving extension set.  The
    # containment argument needs gap-free subsequence semantics — a
    # contiguous (maxgap-constrained) occurrence of the child need not
    # contain one of the parent+item — so the gap rule gates it and
    # contiguous walks keep the full candidate set.
    narrow = params.maxgap is None
    allowed: Optional[np.ndarray] = None      # (P, K) mask; None = all
    depth = 1
    while patterns:
        if depth >= params.max_len:
            # no further expansion possible: every frontier pattern is
            # emitted (the DFS likewise skips the forward-extension check
            # at max_len)
            if depth >= params.min_len:
                out.extend(Pattern(p, int(s)) for p, s in zip(patterns, fsups))
            break
        # extension slots for the whole frontier, once per level (reused
        # across every support chunk below)
        slots = vb.extension_slots(fbits, params.maxgap)
        sup = _frontier_support(slots, cand, params, allowed=allowed)  # (P, K)
        surv = sup >= msc
        has_ext = surv.any(axis=1)                         # maximality mask
        if depth >= params.min_len:
            for p in np.nonzero(~has_ext)[0] if maximal_only else range(len(patterns)):
                out.append(Pattern(patterns[p], int(fsups[p])))
        pidx, kidx = np.nonzero(surv)
        if pidx.size == 0:
            break
        # materialize joined bitmaps only for the surviving (prefix, item)
        # pairs — they *are* the next frontier
        fbits = slots[pidx] & cand[kidx]
        fsups = sup[pidx, kidx]
        patterns = [
            patterns[p] + (int(cand_items[k]),) for p, k in zip(pidx, kidx)
        ]
        if narrow:
            # child (p, k) inherits p's surviving extension row
            allowed = surv[pidx]
        depth += 1
    return out


# ---------------------------------------------------------------------------
# SPAM — vertical bitmaps, all frequent sequential patterns
# ---------------------------------------------------------------------------


def spam(
    db: SequenceDatabase,
    params: MiningParams,
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    return _frontier_mine(db, params, maximal_only=False, vb=vb)


# ---------------------------------------------------------------------------
# VMSP — maximal sequential patterns (the paper's adopted algorithm)
# ---------------------------------------------------------------------------


def maximal_filter(
    patterns: Sequence[Pattern], maxgap: Optional[int]
) -> list[Pattern]:
    """Keep patterns not strictly included in another frequent pattern.

    For the contiguous case (maxgap=1) inclusion = contiguous subsequence;
    otherwise classic subsequence inclusion.  The non-contiguous branch
    buckets accepted maximal patterns by item, so a candidate only scans the
    supersets sharing its rarest item (with an item-multiset prefilter)
    instead of every accepted pattern.
    """
    if not patterns:
        return []
    ordered = sorted(patterns, key=len, reverse=True)
    maximal: list[Pattern] = []
    if maxgap == 1:
        covered: set = set()
        for p in ordered:
            if p.items not in covered:
                maximal.append(p)
                n = len(p.items)
                for i in range(n):
                    for j in range(i + 1, n + 1):
                        if (j - i) < n:
                            covered.add(p.items[i:j])
    else:
        def subseq(a: tuple, b: tuple) -> bool:
            it = iter(b)
            return all(x in it for x in a)

        mcounts: list[Counter] = []       # item multiset per accepted pattern
        buckets: dict = {}                # item -> indices into `maximal`
        for p in ordered:
            pc = Counter(p.items)
            scan: Optional[list] = None   # smallest bucket among p's items
            for it in pc:
                bl = buckets.get(it)
                if bl is None:
                    scan = None           # no accepted pattern contains `it`
                    break
                if scan is None or len(bl) < len(scan):
                    scan = bl
            contained = False
            if scan:
                for mi in scan:
                    m = maximal[mi]
                    if len(m.items) <= len(p.items):
                        continue
                    mc = mcounts[mi]
                    if all(mc[it] >= c for it, c in pc.items()) and subseq(
                        p.items, m.items
                    ):
                        contained = True
                        break
            if not contained:
                idx = len(maximal)
                maximal.append(p)
                mcounts.append(pc)
                for it in pc:
                    buckets.setdefault(it, []).append(idx)
    return maximal


def vmsp(
    db: SequenceDatabase,
    params: MiningParams,
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    """VMSP-style mining: frontier engine + maximality.

    Non-maximal patterns are pruned during the frontier walk via the
    forward-extension mask (a pattern with a frequent s-extension cannot be
    maximal); a global inclusion filter removes backward/infix containment,
    matching VMSP's output semantics.
    """
    candidates = _frontier_mine(db, params, maximal_only=True, vb=vb)
    return maximal_filter(candidates, params.maxgap)


# ---------------------------------------------------------------------------
# PrefixSpan — pattern growth with projected databases
# ---------------------------------------------------------------------------


def prefixspan(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    msc = params.minsup_count(len(db))
    sessions = db.sessions
    out: list[Pattern] = []

    # initial projection: item -> list of (session, end_position)
    first: dict = {}
    for sid, seq in enumerate(sessions):
        for pos, it in enumerate(seq):
            first.setdefault(it, []).append((sid, pos))

    def proj_support(proj: list) -> int:
        return len({sid for sid, _ in proj})

    def grow(pattern: tuple, proj: list) -> None:
        if len(pattern) >= params.min_len:
            out.append(Pattern(pattern, proj_support(proj)))
        if len(pattern) >= params.max_len:
            return
        nxt: dict = {}
        for sid, pos in proj:
            seq = sessions[sid]
            if params.maxgap is None:
                rng = range(pos + 1, len(seq))
            else:
                rng = range(pos + 1, min(pos + 1 + params.maxgap, len(seq)))
            for q in rng:
                nxt.setdefault(seq[q], []).append((sid, q))
        for it, p in nxt.items():
            if proj_support(p) >= msc:
                grow(pattern + (it,), p)

    for it, proj in first.items():
        if proj_support(proj) >= msc:
            grow((it,), proj)
    return out


# ---------------------------------------------------------------------------
# GSP — Apriori BFS over the frontier engine
# ---------------------------------------------------------------------------


def gsp(
    db: SequenceDatabase,
    params: MiningParams,
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    """GSP's level-wise walk *is* the frontier engine: each level holds all
    frequent length-d sequences, candidates are their one-item extensions,
    and the apriori property holds by construction (only frequent prefixes
    are extended, only frequent items are candidate tails).  Support counting
    uses the fused vertical-bitmap join instead of horizontal scans."""
    return _frontier_mine(db, params, maximal_only=False, vb=vb)


# ---------------------------------------------------------------------------
# Oracle + dispatch + dynamic minsup
# ---------------------------------------------------------------------------


def brute_force(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    """Exhaustive window/subsequence counter — the test oracle."""
    counts: dict = {}
    for seq in db.sessions:
        seen: set = set()
        if params.maxgap == 1:
            for i in range(len(seq)):
                for j in range(
                    i + params.min_len, min(i + params.max_len, len(seq)) + 1
                ):
                    seen.add(seq[i:j])
        else:
            def expand(path: tuple, pos: int) -> None:
                if len(path) >= params.min_len:
                    seen.add(path)
                if len(path) >= params.max_len:
                    return
                hi = len(seq) if params.maxgap is None else min(
                    pos + 1 + params.maxgap, len(seq)
                )
                for q in range(pos + 1, hi):
                    expand(path + (seq[q],), q)

            for p0 in range(len(seq)):
                expand((seq[p0],), p0)
        # sorted: dict insertion order must not depend on hash-seeded
        # set iteration
        for s in sorted(seen):
            counts[s] = counts.get(s, 0) + 1
    msc = params.minsup_count(len(db))
    # sorted output: the oracle's pattern order is a function of the
    # data alone, never of per-process hash seeds
    return sorted((Pattern(k, v) for k, v in counts.items() if v >= msc),
                  key=lambda p: p.items)


ALGORITHMS: dict[str, Callable] = {
    "gsp": gsp,
    "spam": spam,
    "prefixspan": prefixspan,
    "vmsp": vmsp,
}

#: algorithms that run on the shared VerticalBitmaps engine and accept a
#: prebuilt ``vb`` (incremental dynamic-minsup / backlog-unchanged reuse)
BITMAP_ALGOS = frozenset({"gsp", "spam", "vmsp"})


def mine(
    db: SequenceDatabase,
    params: MiningParams,
    algo: str = "vmsp",
    vb: Optional[VerticalBitmaps] = None,
) -> list[Pattern]:
    fn = ALGORITHMS[algo]
    if vb is not None and algo in BITMAP_ALGOS:
        return fn(db, params, vb=vb)
    return fn(db, params)


def dynamic_floor_count(
    params: MiningParams, n_sessions: int, start: float, floor: float
) -> int:
    """The support count :func:`mine_dynamic_minsup` builds its bitmaps at —
    callers that cache a ``vb`` for it MUST use this same count (a cache
    built at a higher count would silently drop frequent items).  The
    ``min(floor, start)`` clamp guards the start < floor corner, where the
    first (and only) retry mines below the floor."""
    return dataclasses.replace(
        params, minsup=min(floor, start)
    ).minsup_count(n_sessions)


def mine_dynamic_minsup(
    db: SequenceDatabase,
    params: MiningParams,
    algo: str = "vmsp",
    start: float = 0.5,
    floor: float = 0.01,
    decay: float = 0.5,
    min_patterns: int = 16,
    vb: Optional[VerticalBitmaps] = None,
    vb_factory: Optional[Callable[[], VerticalBitmaps]] = None,
) -> tuple[list[Pattern], float]:
    """Paper §4.2: start with a high minsup and decay it until enough
    frequent sequences are discovered.  Returns (patterns, used_minsup).

    Incremental: for the bitmap algorithms the packed ``VerticalBitmaps``
    are built once at the *floor* support — lazily, on the first decay — and
    re-thresholded per retry (every retry mines at minsup >= floor, so the
    floor-level bitmaps are a superset of what each retry needs; a backlog
    satisfied at ``start`` never pays the floor build).  Pass ``vb`` — built
    at or below the floor count (:func:`dynamic_floor_count`) — to reuse
    bitmaps across calls on an unchanged backlog, or ``vb_factory`` to keep
    the build lazy while still capturing it for caching (it is only invoked
    if a decay retry actually happens, and must build at that same count).
    """
    lazy_floor = vb is None and algo in BITMAP_ALGOS and len(db) > 0
    minsup = start
    patterns: list[Pattern] = []
    while True:
        patterns = mine(db, dataclasses.replace(params, minsup=minsup), algo, vb=vb)
        if len(patterns) >= min_patterns or minsup <= floor:
            return patterns, minsup
        if lazy_floor and vb is None:
            # first decay: build the floor-level bitmaps once and reuse them
            # for every retry.  Deferred past the first mine so a backlog
            # satisfied at `start` never pays the (much larger) floor build.
            if vb_factory is not None:
                vb = vb_factory()
            else:
                vb = VerticalBitmaps(
                    db, dynamic_floor_count(params, len(db), start, floor))
        minsup = max(floor, minsup * decay)
