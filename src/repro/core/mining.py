"""Sequential pattern mining (Palpatine §3.2).

Implements the algorithm families the paper compares, over a shared packed
vertical-bitmap engine (the SPAM/VMSP representation):

* ``gsp``        — Apriori, breadth-first candidate generation.
* ``spam``       — Apriori, depth-first over vertical bitmaps (all patterns).
* ``prefixspan`` — pattern-growth, depth-first projected databases.
* ``vmsp``       — the paper's choice: SPAM-style DFS + *maximal* filtering.

Palpatine's configuration (paper §3.2/§5): single-item itemsets (an access
log is totally ordered), ``maxgap=1`` (consecutive pattern items must be
adjacent in the session), pattern length in [3, 15], dynamic minimum support.

Bitmaps are materialized for *frequent items only* (item support is counted
from the padded session matrix first), so memory is O(freq_items × sessions ×
words) — the back store may hold millions of containers but only the hot set
enters the vertical representation.

The support-counting inner loop (shift + AND + any-bit-per-session reduce)
is the compute hot-spot; ``use_kernel=True`` routes the batched join through
the Pallas TPU kernel in :mod:`repro.kernels.bitmap_support` (validated in
interpret mode on CPU).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from .sessions import SequenceDatabase

__all__ = [
    "MiningParams",
    "Pattern",
    "VerticalBitmaps",
    "mine",
    "gsp",
    "spam",
    "prefixspan",
    "vmsp",
    "maximal_filter",
    "mine_dynamic_minsup",
    "brute_force",
]

_WORD = 32  # packed uint32 words


@dataclasses.dataclass(frozen=True)
class MiningParams:
    """User-specific constraints (paper §3.2 / §5 'Pattern mining')."""

    minsup: float = 0.1          # fraction of sessions
    min_len: int = 3
    max_len: int = 15
    maxgap: Optional[int] = 1    # 1 = contiguous (paper default); None = any
    use_kernel: bool = False     # route support counting through Pallas

    def minsup_count(self, n_sessions: int) -> int:
        return max(1, int(math.ceil(self.minsup * n_sessions)))


@dataclasses.dataclass(frozen=True)
class Pattern:
    items: tuple
    support: int

    def __len__(self) -> int:
        return len(self.items)


# ---------------------------------------------------------------------------
# Vertical packed-bitmap engine (SPAM / VMSP representation)
# ---------------------------------------------------------------------------


class VerticalBitmaps:
    """Per-item occurrence bitmaps for the frequent items, packed 32
    positions/word.

    ``bits[r]`` has shape (n_sessions, n_words); bit ``p % 32`` of word
    ``p // 32`` for session ``s`` is set iff item ``freq_items[r]`` occurs at
    position ``p`` of session ``s``.  Padding positions are never set, so
    joining with an item bitmap implicitly masks shifted-past-the-end bits.
    """

    def __init__(self, db: SequenceDatabase, minsup_count: int = 1):
        mat, _ = db.padded_matrix()
        self.n_sessions = mat.shape[0]
        max_len = mat.shape[1] if mat.size else 0
        self.n_words = max(1, (max_len + _WORD - 1) // _WORD)

        if mat.size:
            sess, pos = np.nonzero(mat >= 0)
            item = mat[sess, pos]
            # item support = #sessions containing the item (count unique pairs)
            pair = sess.astype(np.int64) * max(db.n_items, 1) + item
            uniq = np.unique(pair)
            per_item = np.bincount(
                (uniq % max(db.n_items, 1)).astype(np.int64), minlength=db.n_items
            )
            self.freq_items = np.nonzero(per_item >= minsup_count)[0].astype(np.int32)
            self.freq_support = per_item[self.freq_items].astype(np.int64)
            row_of = np.full(db.n_items, -1, np.int32)
            row_of[self.freq_items] = np.arange(self.freq_items.size, dtype=np.int32)
            keep = row_of[item] >= 0
            sess, pos, item = sess[keep], pos[keep], item[keep]
            bits = np.zeros(
                (self.freq_items.size, self.n_sessions, self.n_words), np.uint32
            )
            word, bit = pos // _WORD, pos % _WORD
            np.bitwise_or.at(
                bits,
                (row_of[item], sess, word),
                (np.uint32(1) << bit.astype(np.uint32)),
            )
            self._row_of = row_of
        else:
            self.freq_items = np.zeros((0,), np.int32)
            self.freq_support = np.zeros((0,), np.int64)
            self._row_of = np.full(db.n_items, -1, np.int32)
            bits = np.zeros((0, self.n_sessions, self.n_words), np.uint32)
        self.bits = bits

    def row(self, item_id: int) -> int:
        r = int(self._row_of[item_id])
        if r < 0:
            raise KeyError(f"item {item_id} is not frequent")
        return r

    # -- primitive ops ------------------------------------------------------
    @staticmethod
    def shift1(b: np.ndarray) -> np.ndarray:
        """Move every set bit one position later (possible extension slots
        for maxgap=1).  Works on (..., n_words)."""
        carry = np.zeros_like(b)
        carry[..., 1:] = b[..., :-1] >> np.uint32(31)
        return ((b << np.uint32(1)) | carry).astype(np.uint32)

    @classmethod
    def smear_after(cls, b: np.ndarray) -> np.ndarray:
        """Set all positions strictly after the first set bit per session
        (SPAM's s-step transform for unconstrained gap)."""
        x = b.copy()
        for k in (1, 2, 4, 8, 16):  # within-word smear toward higher bits
            x |= x << np.uint32(k)
        after = cls.shift1(x)
        # any earlier word nonzero -> whole word saturates
        nz = (b != 0).astype(np.uint32)
        earlier = np.cumsum(nz, axis=-1) - nz  # count of nonzero earlier words
        after[earlier > 0] = np.uint32(0xFFFFFFFF)
        return after

    def extension_slots(self, b: np.ndarray, maxgap: Optional[int]) -> np.ndarray:
        if maxgap is None:
            return self.smear_after(b)
        out = self.shift1(b)
        acc = out
        for _ in range(maxgap - 1):
            acc = self.shift1(acc)
            out = out | acc
        return out

    @staticmethod
    def support(b: np.ndarray) -> np.ndarray:
        """#sessions with >=1 set bit.  (..., S, W) -> (...,)."""
        return np.any(b != 0, axis=-1).sum(axis=-1)

    # -- batched s-step join (the hot loop; kernel-accelerated) -------------
    def sstep_join(
        self,
        prefix_bits: np.ndarray,
        cand_rows: np.ndarray,
        maxgap: Optional[int],
        use_kernel: bool = False,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Join a prefix bitmap against candidate item bitmaps (by row).

        Returns ``(joined (K,S,W), support (K,))`` where ``joined[k]`` marks
        end positions of ``prefix + (freq_items[cand_rows[k]],)``.
        """
        slots = self.extension_slots(prefix_bits, maxgap)
        cand = self.bits[cand_rows]
        if use_kernel:
            from repro.kernels.bitmap_support import ops as _ops

            joined, sup = _ops.sstep_join_support(slots, cand)
            return np.asarray(joined), np.asarray(sup)
        joined = slots[None, :, :] & cand
        return joined, self.support(joined)


# ---------------------------------------------------------------------------
# SPAM — DFS over vertical bitmaps, all frequent sequential patterns
# ---------------------------------------------------------------------------


def _dfs_mine(
    db: SequenceDatabase, params: MiningParams, maximal_only: bool
) -> list[Pattern]:
    vb = VerticalBitmaps(db, params.minsup_count(len(db)))
    msc = params.minsup_count(len(db))
    all_rows = np.arange(vb.freq_items.size)
    out: list[Pattern] = []

    def dfs(pattern: tuple, pbits: np.ndarray, sup: int) -> None:
        has_freq_ext = False
        if len(pattern) < params.max_len and all_rows.size:
            joined, sups = vb.sstep_join(
                pbits, all_rows, params.maxgap, params.use_kernel
            )
            for k in np.nonzero(sups >= msc)[0]:
                has_freq_ext = True
                dfs(
                    pattern + (int(vb.freq_items[k]),),
                    joined[k],
                    int(sups[k]),
                )
        if len(pattern) >= params.min_len and (not maximal_only or not has_freq_ext):
            out.append(Pattern(pattern, int(sup)))

    for r in range(vb.freq_items.size):
        dfs((int(vb.freq_items[r]),), vb.bits[r], int(vb.freq_support[r]))
    return out


def spam(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    return _dfs_mine(db, params, maximal_only=False)


# ---------------------------------------------------------------------------
# VMSP — maximal sequential patterns (the paper's adopted algorithm)
# ---------------------------------------------------------------------------


def maximal_filter(
    patterns: Sequence[Pattern], maxgap: Optional[int]
) -> list[Pattern]:
    """Keep patterns not strictly included in another frequent pattern.

    For the contiguous case (maxgap=1) inclusion = contiguous subsequence;
    otherwise classic subsequence inclusion.
    """
    if not patterns:
        return []
    ordered = sorted(patterns, key=len, reverse=True)
    maximal: list[Pattern] = []
    if maxgap == 1:
        covered: set = set()
        for p in ordered:
            if p.items not in covered:
                maximal.append(p)
                n = len(p.items)
                for i in range(n):
                    for j in range(i + 1, n + 1):
                        if (j - i) < n:
                            covered.add(p.items[i:j])
    else:
        def subseq(a: tuple, b: tuple) -> bool:
            it = iter(b)
            return all(x in it for x in a)

        for p in ordered:
            if not any(
                len(m.items) > len(p.items) and subseq(p.items, m.items)
                for m in maximal
            ):
                maximal.append(p)
    return maximal


def vmsp(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    """VMSP-style mining: DFS with vertical bitmaps + maximality.

    Non-maximal patterns are pruned during the DFS via the forward-extension
    check (a pattern with a frequent s-extension cannot be maximal); a global
    inclusion filter removes backward/infix containment, matching VMSP's
    output semantics.
    """
    candidates = _dfs_mine(db, params, maximal_only=True)
    return maximal_filter(candidates, params.maxgap)


# ---------------------------------------------------------------------------
# PrefixSpan — pattern growth with projected databases
# ---------------------------------------------------------------------------


def prefixspan(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    msc = params.minsup_count(len(db))
    sessions = db.sessions
    out: list[Pattern] = []

    # initial projection: item -> list of (session, end_position)
    first: dict = {}
    for sid, seq in enumerate(sessions):
        for pos, it in enumerate(seq):
            first.setdefault(it, []).append((sid, pos))

    def proj_support(proj: list) -> int:
        return len({sid for sid, _ in proj})

    def grow(pattern: tuple, proj: list) -> None:
        if len(pattern) >= params.min_len:
            out.append(Pattern(pattern, proj_support(proj)))
        if len(pattern) >= params.max_len:
            return
        nxt: dict = {}
        for sid, pos in proj:
            seq = sessions[sid]
            if params.maxgap is None:
                rng = range(pos + 1, len(seq))
            else:
                rng = range(pos + 1, min(pos + 1 + params.maxgap, len(seq)))
            for q in rng:
                nxt.setdefault(seq[q], []).append((sid, q))
        for it, p in nxt.items():
            if proj_support(p) >= msc:
                grow(pattern + (it,), p)

    for it, proj in first.items():
        if proj_support(proj) >= msc:
            grow((it,), proj)
    return out


# ---------------------------------------------------------------------------
# GSP — Apriori BFS candidate generation
# ---------------------------------------------------------------------------


def gsp(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    vb = VerticalBitmaps(db, params.minsup_count(len(db)))
    msc = params.minsup_count(len(db))
    level = {
        (int(vb.freq_items[r]),): (vb.bits[r], int(vb.freq_support[r]))
        for r in range(vb.freq_items.size)
    }
    out: list[Pattern] = []
    length = 1
    while level and length < params.max_len:
        # candidate generation: join p, q with p[1:] == q[:-1]
        # (keying by each pattern's prefix makes the apriori check — the
        # candidate's suffix pat[1:]+(t,) is frequent — hold by construction)
        by_prefix: dict = {}
        for pat in level:
            by_prefix.setdefault(pat[:-1], []).append(pat)
        nxt: dict = {}
        for pat, (pbits, _) in level.items():
            tails = [q[-1] for q in by_prefix.get(pat[1:], [])]
            for t in dict.fromkeys(tails):
                cand = pat + (t,)
                if cand in nxt:
                    continue
                joined, sup = vb.sstep_join(
                    pbits,
                    np.array([vb.row(t)]),
                    params.maxgap,
                    params.use_kernel,
                )
                if sup[0] >= msc:
                    nxt[cand] = (joined[0], int(sup[0]))
        length += 1
        level = nxt
        for pat, (_, sup) in level.items():
            if params.min_len <= len(pat) <= params.max_len:
                out.append(Pattern(pat, sup))
    return out


# ---------------------------------------------------------------------------
# Oracle + dispatch + dynamic minsup
# ---------------------------------------------------------------------------


def brute_force(db: SequenceDatabase, params: MiningParams) -> list[Pattern]:
    """Exhaustive window/subsequence counter — the test oracle."""
    counts: dict = {}
    for seq in db.sessions:
        seen: set = set()
        if params.maxgap == 1:
            for i in range(len(seq)):
                for j in range(
                    i + params.min_len, min(i + params.max_len, len(seq)) + 1
                ):
                    seen.add(seq[i:j])
        else:
            def expand(path: tuple, pos: int) -> None:
                if len(path) >= params.min_len:
                    seen.add(path)
                if len(path) >= params.max_len:
                    return
                hi = len(seq) if params.maxgap is None else min(
                    pos + 1 + params.maxgap, len(seq)
                )
                for q in range(pos + 1, hi):
                    expand(path + (seq[q],), q)

            for p0 in range(len(seq)):
                expand((seq[p0],), p0)
        for s in seen:
            counts[s] = counts.get(s, 0) + 1
    msc = params.minsup_count(len(db))
    return [Pattern(k, v) for k, v in counts.items() if v >= msc]


ALGORITHMS: dict[str, Callable] = {
    "gsp": gsp,
    "spam": spam,
    "prefixspan": prefixspan,
    "vmsp": vmsp,
}


def mine(db: SequenceDatabase, params: MiningParams, algo: str = "vmsp") -> list[Pattern]:
    return ALGORITHMS[algo](db, params)


def mine_dynamic_minsup(
    db: SequenceDatabase,
    params: MiningParams,
    algo: str = "vmsp",
    start: float = 0.5,
    floor: float = 0.01,
    decay: float = 0.5,
    min_patterns: int = 16,
) -> tuple[list[Pattern], float]:
    """Paper §4.2: start with a high minsup and decay it until enough
    frequent sequences are discovered.  Returns (patterns, used_minsup)."""
    minsup = start
    patterns: list[Pattern] = []
    while True:
        patterns = mine(db, dataclasses.replace(params, minsup=minsup), algo)
        if len(patterns) >= min_patterns or minsup <= floor:
            return patterns, minsup
        minsup = max(floor, minsup * decay)
