"""Version shims for the jax APIs that moved between 0.4.x and 0.5+.

Kept dependency-free and import-cheap: models, training, and launch all
import from here, so this module must not touch device state.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "tpu_compiler_params"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the 0.4.x fallback.

    On 0.4.x the function lives in ``jax.experimental.shard_map`` and the
    "don't statically check replication" flag is ``check_rep`` rather than
    ``check_vma``; semantics are identical for our uses.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as fn04
    return fn04(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` was named ``TPUCompilerParams`` on 0.4.x."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
